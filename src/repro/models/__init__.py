from repro.models import base, layers, lm, mla, moe, rglru, xlstm  # noqa: F401
