"""The paper's contribution: automatic horizontal fusion for TPU/Pallas.

op_spec    — fusible-op IR (1-D grid + BlockSpecs + resource profile)
cost_model — 3-term roofline scoring (the napkin-math engine)
hfuse      — Generate(): the fused pallas_call builder (+ vfuse baseline)
autotuner  — Main(): schedule x variant x VMEM-cap search (Fig. 6)
planner    — graph-level pairing of memory-bound x compute-bound ops
"""
from repro.core import autotuner, cost_model, hfuse, op_spec, planner  # noqa: F401
