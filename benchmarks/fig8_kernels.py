"""Paper Fig. 8: per-kernel resource metrics.

GPU metrics have no TPU equivalents; the analogous roofline quantities:
  issue-slot utilization  -> engine utilization  min(tc,tm)/max(tc,tm)
  MemInst stall %         -> memory-bound fraction  tm/(tc+tm)
  occupancy               -> VMEM pipeline headroom  budget/(2*working set)
"""
from __future__ import annotations

from benchmarks.common import csv_row
from repro.core.cost_model import VMEM_BUDGET
from repro.kernels import paper_suite as ps


def run():
    csv_row("kernel", "grid", "flops", "hbm_bytes", "arith_intensity",
            "bound", "t_native_us", "engine_util_pct", "membound_frac_pct",
            "vmem_headroom_x")
    for name, f in ps.ALL_KERNELS.items():
        op, _, _ = f()
        tc, tm = op.t_compute, op.t_memory
        util = 100.0 * min(tc, tm) / max(tc, tm)
        memfrac = 100.0 * tm / (tc + tm)
        headroom = VMEM_BUDGET / (2.0 * op.vmem_bytes)
        csv_row(name, op.grid, f"{op.flops:.3e}", f"{op.hbm_bytes:.3e}",
                round(op.arithmetic_intensity, 2), op.bound,
                round(op.t_native * 1e6, 2), round(util, 1),
                round(memfrac, 1), round(headroom, 1))


if __name__ == "__main__":
    run()
