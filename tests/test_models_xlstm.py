"""mLSTM chunked-parallel form == sequential recurrence (the xLSTM
correctness core), plus RG-LRU associative-scan == step recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (see "
                           "requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.models import rglru, xlstm


def _rand_qkvg(key, B, S, H, dk, dv):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    ip = jax.random.normal(ks[3], (B, S, H)) * 2.0
    fp = jax.random.normal(ks[4], (B, S, H)) * 2.0 + 2.0
    return q, k, v, ip, fp


@pytest.mark.parametrize("S,chunk", [(16, 4), (32, 8), (64, 64), (48, 16)])
def test_mlstm_chunked_equals_sequential(S, chunk, rng):
    B, H, dk, dv = 2, 3, 8, 16
    q, k, v, ip, fp = _rand_qkvg(rng, B, S, H, dk, dv)
    st0 = xlstm.mlstm_fresh_state(B, H, dk, dv)
    h_seq, s_seq = xlstm.mlstm_seq(q, k, v, ip, fp, st0)
    h_chk, s_chk = xlstm.mlstm_chunked(q, k, v, ip, fp, st0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_seq),
                               rtol=2e-4, atol=2e-4)
    for a, b in zip(s_chk, s_seq):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(2, 24), seed=st.integers(0, 2 ** 30))
def test_mlstm_chunked_property(S, seed):
    """Property: any (S, gate) draw — chunked(LS=S) == sequential."""
    B, H, dk, dv = 1, 2, 4, 4
    q, k, v, ip, fp = _rand_qkvg(jax.random.PRNGKey(seed), B, S, H, dk, dv)
    st0 = xlstm.mlstm_fresh_state(B, H, dk, dv)
    h_seq, _ = xlstm.mlstm_seq(q, k, v, ip, fp, st0)
    h_chk, _ = xlstm.mlstm_chunked(q, k, v, ip, fp, st0, chunk=S)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_seq),
                               rtol=5e-4, atol=5e-4)


def test_mlstm_state_carry_across_calls(rng):
    """Splitting a sequence across two chunked calls == one call."""
    B, H, dk, dv = 1, 2, 4, 8
    S = 32
    q, k, v, ip, fp = _rand_qkvg(rng, B, S, H, dk, dv)
    st0 = xlstm.mlstm_fresh_state(B, H, dk, dv)
    h_all, _ = xlstm.mlstm_chunked(q, k, v, ip, fp, st0, chunk=8)
    h1, st1 = xlstm.mlstm_chunked(q[:, :16], k[:, :16], v[:, :16],
                                  ip[:, :16], fp[:, :16], st0, chunk=8)
    h2, _ = xlstm.mlstm_chunked(q[:, 16:], k[:, 16:], v[:, 16:],
                                ip[:, 16:], fp[:, 16:], st1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(h_all), rtol=2e-4, atol=2e-4)


def test_rglru_scan_equals_steps(rng):
    from repro.configs import get_config
    cfg = get_config("recurrentgemma-2b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    from repro.models.base import init_params
    p = init_params(rglru.spec(cfg), rng, jnp.float32)
    B, S = 2, 8
    W = cfg.lru_width or cfg.d_model
    rec = jax.random.normal(rng, (B, S, W), jnp.float32)
    y_scan, h_last = rglru.rg_lru_scan(p, rec)
    h = jnp.zeros((B, W), jnp.float32)
    outs = []
    for t in range(S):
        y_t, h = rglru.rg_lru_step(p, rec[:, t], h)
        outs.append(y_t)
    y_step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=1e-5, atol=1e-5)
