"""Persistent schedule cache — never re-search a bundle we already tuned.

Production serving/training plans the same op graphs every process start;
the paper's Main() search (and especially its measured form) is pure waste
the second time.  Entries are keyed by an exact *bundle signature* — op
names, grids, operand shapes/dtypes/block shapes, FLOP/byte counts, the
VMEM budget, and the scoring mode (cost model vs measurement backend) — so
any change that could alter the tuned schedule changes the key and the
stale entry is simply never consulted again.  Bumping ``CACHE_VERSION``
(schema or search-semantics changes) invalidates every file on disk.

File format (JSON, human-inspectable):

    {"version": 4,
     "entries": {"<sha256-prefix>": {
        "members": ["maxpool", "upsample", "sha_like"],
        "ratios": [2, 1, 4], "variant": 0, "vmem_cap": null,
        "predicted_s": 1.2e-4, "measured_s": 1.3e-4, "delta_pct": 8.3,
        "mode": "costmodel"}},
     "meta": {"<sha256-prefix>": {"last_used": 7, "uses": 3}},
     "clock": 9}

``meta``/``clock`` are the LRU + staleness side table (entries themselves
stay exactly what the search stored); ``max_entries`` bounds the table with
least-recently-used eviction.  ``autotuner.search(cache=...)`` and
``planner.plan(cache=...)`` consult it; ``default_cache()`` resolves the
shared on-disk location (``$REPRO_SCHEDULE_CACHE`` or
``~/.cache/repro/schedule_cache.json``; ``$REPRO_SCHEDULE_CACHE_MAX``
bounds it, default 512).  ``python -m repro.tools cache-inspect`` dumps
entries, cm-vs-measured deltas, and stale-signature stats.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
from pathlib import Path
from typing import Optional, Sequence

import jax.numpy as jnp

from repro.core.op_spec import OpSpec

CACHE_VERSION = 4      # v4: signatures carry the mesh-axis tag (tensor-
#                        parallel plans tune shard-local operand shapes and
#                        must never resolve a single-device schedule)

_DEFAULT: Optional["ScheduleCache"] = None


def bundle_signature(ops: Sequence[OpSpec], *, vmem_budget: int,
                     mode: str = "costmodel", mesh_tag: str = "") -> str:
    """Exact identity of a tuning problem.  Includes everything the search
    outcome can depend on; excludes anything it cannot (body closures).

    ``mesh_tag`` names the SPMD context a sharded plan tunes for (e.g.
    ``"model:4"`` — the mesh axis and its extent).  Per-shard operand
    shapes alone already differ from the single-device plan, but two
    different meshes can produce identical shard-local shapes (8 heads on
    2 shards vs 4 heads unsharded), so the tag is part of the identity."""
    parts = [f"v{CACHE_VERSION}", mode, str(int(vmem_budget))]
    if mesh_tag:
        parts.append(f"mesh[{mesh_tag}]")
    for op in ops:
        operands = ",".join(
            "{}:{}:{}".format("x".join(map(str, o.shape)),
                              jnp.dtype(o.dtype).name,
                              "x".join(map(str, o.block_shape)))
            for o in (*op.inputs, *op.outputs))
        # a stitched chain (core/stitch.py) tunes differently from the
        # unstitched op set — same operands, different traffic and VMEM
        # residency — so the chain structure is part of the identity
        chain = f"|c[{'>'.join(op.chain)}]+{int(op.extra_vmem_bytes)}" \
            if op.chain else ""
        parts.append(f"{op.name}|g{op.grid}|f{op.flops:.6g}"
                     f"|h{op.hbm_bytes:.6g}|{operands}{chain}")
    return hashlib.sha256(";".join(parts).encode()).hexdigest()[:32]


class ScheduleCache:
    """In-memory dict with optional JSON persistence, hit/miss stats, a
    size bound with LRU eviction, and per-entry usage metadata.

    ``max_entries`` bounds the table: on ``put`` the least-recently-used
    entries are evicted first (usage rides in a side table, NOT inside the
    entries — entry dicts stay exactly what callers stored).  The usage
    metadata (a monotonic ``clock``, per-key ``last_used``/``uses``)
    persists with the file so ``repro.tools cache-inspect`` can report
    stale signatures — entries no plan has consulted since they were
    recorded (the bundle shape changed and the old key is dead weight)."""

    def __init__(self, path: Optional[os.PathLike | str] = None,
                 max_entries: Optional[int] = None):
        self.path = Path(path) if path else None
        self.max_entries = max_entries
        self.entries: dict[str, dict] = {}
        self.meta: dict[str, dict] = {}       # key -> {last_used, uses}
        self.clock = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._defer = False
        self._dirty = False
        if self.path is not None:
            self.load()

    # ------------------------------------------------------------------
    def _touch(self, key: str, used: bool) -> None:
        self.clock += 1
        m = self.meta.setdefault(key, {"last_used": 0, "uses": 0})
        m["last_used"] = self.clock
        if used:
            m["uses"] = m.get("uses", 0) + 1
            # hit-side usage persists at the next save: a pure-hit replan
            # inside batched() (planner.plan) flushes once on exit
            if self._defer:
                self._dirty = True

    def get(self, key: str) -> Optional[dict]:
        entry = self.entries.get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
            self._touch(key, used=True)
        return entry

    def put(self, key: str, entry: dict) -> None:
        self.entries[key] = entry
        self._touch(key, used=False)
        if self.max_entries is not None:
            while len(self.entries) > self.max_entries:
                victim = min(
                    (k for k in self.entries if k != key),
                    key=lambda k: self.meta.get(k, {}).get("last_used", 0))
                del self.entries[victim]
                self.meta.pop(victim, None)
                self.evictions += 1
        if self._defer:
            self._dirty = True
        elif self.path is not None:
            self.save()

    @contextlib.contextmanager
    def batched(self):
        """Defer disk writes until the block exits — one save for a whole
        plan()/search() burst instead of a full-file rewrite per put()."""
        prev = self._defer
        self._defer = True
        try:
            yield self
        finally:
            self._defer = prev
            if self._dirty and not self._defer:
                self._dirty = False
                self.save()

    def __len__(self) -> int:
        return len(self.entries)

    # ------------------------------------------------------------------
    def load(self) -> None:
        if self.path is None or not self.path.exists():
            return
        try:
            blob = json.loads(self.path.read_text())
        except (json.JSONDecodeError, OSError):
            return                            # corrupt cache == empty cache
        if blob.get("version") != CACHE_VERSION:
            return                            # stale schema: discard
        self.entries.update(blob.get("entries", {}))
        self.meta.update(blob.get("meta", {}))
        self.clock = max(self.clock, int(blob.get("clock", 0)))

    def save(self) -> None:
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        # merge concurrent writers: keys are content-addressed, so entries
        # another process added since our load are kept (ours win on clash)
        merged = dict(self.entries)
        merged_meta = dict(self.meta)
        clock = self.clock
        try:
            blob = json.loads(self.path.read_text())
            if blob.get("version") == CACHE_VERSION:
                merged = {**blob.get("entries", {}), **self.entries}
                merged_meta = {**blob.get("meta", {}), **self.meta}
                clock = max(clock, int(blob.get("clock", 0)))
        except (FileNotFoundError, json.JSONDecodeError, OSError):
            pass
        merged_meta = {k: m for k, m in merged_meta.items() if k in merged}
        if self.max_entries is not None:          # bound survives the merge:
            while len(merged) > self.max_entries:  # evicted keys stay evicted
                victim = min(merged,
                             key=lambda k: merged_meta.get(k, {})
                             .get("last_used", 0))
                del merged[victim]
                merged_meta.pop(victim, None)
        tmp = self.path.with_suffix(f".tmp.{os.getpid()}")   # no writer races
        tmp.write_text(json.dumps(
            {"version": CACHE_VERSION, "entries": merged,
             "meta": merged_meta, "clock": clock},
            indent=1, sort_keys=True))
        tmp.replace(self.path)                # atomic on POSIX
        self.entries = merged
        self.meta = merged_meta
        self.clock = clock

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate view for ``repro.tools cache-inspect``."""
        deltas = [e["delta_pct"] for e in self.entries.values()
                  if isinstance(e, dict) and e.get("delta_pct") is not None]
        stale = [k for k in self.entries
                 if self.meta.get(k, {}).get("uses", 0) == 0]
        return {
            "path": str(self.path) if self.path else None,
            "entries": len(self.entries),
            "measured": sum(1 for e in self.entries.values()
                            if isinstance(e, dict)
                            and e.get("measured_s") is not None),
            "stale_never_reused": len(stale),
            "mean_abs_delta_pct": (sum(abs(d) for d in deltas) / len(deltas)
                                   if deltas else None),
            "max_abs_delta_pct": (max(abs(d) for d in deltas)
                                  if deltas else None),
            "clock": self.clock,
        }


def default_cache() -> ScheduleCache:
    """Process-wide cache at $REPRO_SCHEDULE_CACHE (or ~/.cache/repro/),
    size-bounded by $REPRO_SCHEDULE_CACHE_MAX (LRU, default 512)."""
    global _DEFAULT
    if _DEFAULT is None:
        path = os.environ.get(
            "REPRO_SCHEDULE_CACHE",
            str(Path.home() / ".cache" / "repro" / "schedule_cache.json"))
        bound = int(os.environ.get("REPRO_SCHEDULE_CACHE_MAX", "512"))
        _DEFAULT = ScheduleCache(path, max_entries=bound or None)
    return _DEFAULT
