"""Logical-axis sharding: rules tables, PartitionSpec resolution, and the
ambient-mesh `shard()` constraint helper used inside model code.

Model code annotates tensors with *logical* axes ("batch", "seq", "embed",
"heads", ...).  A per-family rules table maps logical axes to mesh axes.
Resolution is shape-aware: a logical axis whose dim is not divisible by the
mapped mesh-axis extent degrades to replication for that dim (never a
compile error — e.g. batch=1 long-context decode).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, tuple]

# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------
# Default single/multi-pod rules.  "data" resolves to ("pod","data") on a
# multi-pod mesh (pure DP across pods), "model" to the intra-pod model axis.
BASE_RULES: dict[str, str] = {
    # activations
    "batch": "data",
    "seq": None,
    "sp_seq": "model",       # sequence-parallel sections (norms, elementwise)
    "kv_seq": "model",       # sequence-sharded KV cache (distributed flash-decode)
    "embed": None,
    "act_ffn": "model",
    "act_heads": "model",
    "act_vocab": "model",
    # params
    "ffn": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv": "model",
    "vocab": "model",
    "expert": "model",
    "expert_ffn": "model",
    "capacity": None,
    "lru": "model",
    "layer": None,
    "kv_lora": None,
    "q_lora": None,
}

# Family overrides.  moe-huge (DeepSeek-V2-236B): expert count shards over the
# data axis (the expert corpus is the bulk of the 236B params — FSDP-style),
# expert hidden dim over model.
FAMILY_OVERRIDES: dict[str, dict[str, MeshAxes]] = {
    # DeepSeek-V2 class: the 222B expert corpus FSDP-shards its expert dim
    # over 'data'; capacity buffers shard over 'model' so per-device MoE
    # activations stay O(tokens/devices).
    "moe-huge": {"expert": "data", "expert_ffn": "model", "capacity": "model"},
    # dense archs at train shapes: pure FSDP (see rules_for docstring)
    "fsdp-train": {
        "batch": ("data", "model"),
        "embed": ("data", "model"),        # params shard on their embed dim
        "ffn": None, "heads": None, "kv_heads": None, "qkv": None,
        "vocab": None, "lru": None, "act_ffn": None, "act_heads": None,
        "act_vocab": None, "sp_seq": None, "kv_seq": None,
    },
}


def rules_for(cfg, mesh: Mesh, kind: str = "") -> dict[str, MeshAxes]:
    """Logical→mesh rules, specialized per family and workload kind.

    §Perf iteration 1 (EXPERIMENTS.md): at train shapes the global batch
    covers the whole mesh, and naive TP-16 is collective-bound (the backward
    of every TP matmul psums a (B,S,d) activation gradient: measured 289
    GB/chip/step on granite train_4k — tcoll 6.6s vs tc 0.74s).  For non-MoE
    archs whose params fit per-chip under full sharding, train shapes
    therefore switch to FSDP: batch over (data×model), params sharded over
    the combined mesh on their embed dim, no tensor parallelism — collective
    traffic becomes ~3×params of weight gathers (granite: 15GB, 0.3s).
    Prefill/decode keep TP (batch < mesh size there).
    """
    rules = dict(BASE_RULES)
    fam = cfg.family
    # moe-huge: per-layer expert corpus too large for model-axis sharding
    # alone (>= 1B params/layer => >= 125MB/chip at TP16 just for one layer)
    if cfg.is_moe and cfg.moe.num_experts * cfg.moe.d_ff_expert * cfg.d_model * 3 > 1e9:
        fam = "moe-huge"
    if kind == "train" and not cfg.is_moe:
        fam = "fsdp-train"
    rules.update(FAMILY_OVERRIDES.get(fam, {}))
    # map "data" -> ("pod","data") when a pod axis exists (pure DP over pods)
    if "pod" in mesh.axis_names:
        def remap(v):
            if v == "data":
                return ("pod", "data")
            if isinstance(v, tuple) and "data" in v:
                out = []
                for a in v:
                    out.extend(("pod", "data") if a == "data" else (a,))
                return tuple(out)
            return v
        rules = {k: remap(v) for k, v in rules.items()}
    return rules


# ---------------------------------------------------------------------------
# Ambient mesh context
# ---------------------------------------------------------------------------
class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[dict] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[dict] = None):
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------
def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve_pspec(logical: Sequence[Optional[str]], shape: Sequence[int],
                  mesh: Mesh, rules: dict[str, MeshAxes]) -> P:
    """Shape-aware logical→mesh resolution; drops non-divisible dims to None.
    Never assigns one mesh axis to two dims."""
    used: set[str] = set()
    out = []
    for name, dim in zip(logical, shape):
        target = rules.get(name) if name else None
        if target is None:
            out.append(None)
            continue
        tgt_tuple = (target,) if isinstance(target, str) else tuple(target)
        if any(a in used for a in tgt_tuple):
            out.append(None)
            continue
        if dim % _axis_size(mesh, tgt_tuple) != 0:
            # try a prefix of the tuple (e.g. only "pod" of ("pod","data"))
            ok = None
            for cut in range(len(tgt_tuple) - 1, 0, -1):
                sub = tgt_tuple[:cut]
                if dim % _axis_size(mesh, sub) == 0 and not any(a in used for a in sub):
                    ok = sub
                    break
            if ok is None:
                out.append(None)
                continue
            tgt_tuple = ok
        used.update(tgt_tuple)
        out.append(tgt_tuple[0] if len(tgt_tuple) == 1 else tgt_tuple)
    return P(*out)


def data_shards() -> int:
    """Extent of the (pod×)data axes of the ambient mesh (1 when unset).
    Used by the MoE grouped dispatch to keep token gathers shard-local."""
    mesh, rules = _CTX.mesh, _CTX.rules or BASE_RULES
    if mesh is None:
        return 1
    return _axis_size(mesh, rules.get("batch", "data"))


def _manual_axes() -> frozenset:
    """Mesh axes currently under manual shard_map control (e.g. 'pod' inside
    the int8-compressed gradient region) — constraints must not mention them."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or am.empty:
            return frozenset()
        return frozenset(n for n, t in zip(am.axis_names, am.axis_types)
                         if str(t) == "Manual")
    except Exception:
        pass
    # jax 0.4.x: no abstract mesh — a shard_map-manual axis is bound in the
    # trace's axis env exactly like a pmap axis, so probe each mesh axis
    mesh = _CTX.mesh
    if mesh is None:
        return frozenset()
    from jax._src import core as _core
    manual = set()
    for name in mesh.axis_names:
        try:
            _core.axis_frame(name)
            manual.add(name)
        except Exception:
            continue
    return frozenset(manual)


def shard(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint against the ambient mesh (no-op when unset)."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None:
        return x
    rules = rules or BASE_RULES
    spec = resolve_pspec(logical, x.shape, mesh, rules)
    manual = _manual_axes()
    if manual:
        def drop(e):
            if e is None:
                return None
            t = (e,) if isinstance(e, str) else tuple(e)
            t = tuple(a for a in t if a not in manual)
            return None if not t else (t[0] if len(t) == 1 else t)
        spec = P(*(drop(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Param / state sharding trees
# ---------------------------------------------------------------------------
def sharding_tree(abstract_tree, axes_tree, mesh: Mesh, rules: dict):
    """NamedSharding pytree matching an abstract-value pytree.

    Walks nested dicts manually: axes leaves are tuples (which jax.tree would
    otherwise traverse as containers)."""
    def walk(ab, ax):
        if isinstance(ab, dict):
            return {k: walk(ab[k], ax[k]) for k in ab}
        return NamedSharding(mesh, resolve_pspec(ax, ab.shape, mesh, rules))
    return walk(abstract_tree, axes_tree)


def param_shardings(specs, mesh: Mesh, rules: dict, dtype="bfloat16"):
    import jax.numpy as jnp
    from repro.models.base import abstract_params, logical_axes
    ab = abstract_params(specs, jnp.dtype(dtype))
    ax = logical_axes(specs)
    return sharding_tree(ab, ax, mesh, rules)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Tensor-parallel serve helpers (head-sharded executed decode; serve/engine)
# ---------------------------------------------------------------------------
# The serve engine shards the decode program along attention heads / FFN
# width: each shard owns H/n query heads, Hkv/n KV heads and d_ff/n FFN
# columns, activations (d_model) stay replicated, and the two row-sharded
# output projections (w_o, w_out) psum their partial products.  Two fused
# weights need a COLUMN PERMUTATION before the even last-axis split hands
# each shard a self-consistent slab:
#
#   w_qkv (d, (H+2*Hkv)*D)  columns are [q_0..q_{H-1} | k_0.. | v_0..] —
#       a plain split would give shard 0 query heads only.  Permuted to
#       shard-major [q_s | k_s | v_s] per shard, the engine's head-split
#       glue works unchanged with local head counts.
#   w_in  (d, 2*d_ff)       gated activations store [gate | up]; permuted
#       to per-shard [gate_s | up_s] so the split-in-half gate math stays
#       local.  (Non-gated w_in needs no permutation.)
#
# Row-sharded weights (w_o rows are head-major, w_out rows follow the
# activation's column order) split evenly without reordering.

_TP_COL_SHARDED = ("w_qkv", "w_in")     # shard last axis (after permutation)
_TP_ROW_SHARDED = ("w_o", "w_out")      # shard axis -2; psum after matmul


def tp_qkv_permutation(H: int, Hkv: int, D: int, shards: int) -> np.ndarray:
    """Column permutation taking [q|k|v] to shard-major [q_s|k_s|v_s]."""
    if H % shards or Hkv % shards:
        raise ValueError(f"H={H}, Hkv={Hkv} not divisible by {shards} shards")
    Hl, Hkvl = H // shards * D, Hkv // shards * D
    idx = []
    for s in range(shards):
        idx.extend(range(s * Hl, (s + 1) * Hl))
        idx.extend(range(H * D + s * Hkvl, H * D + (s + 1) * Hkvl))
        idx.extend(range((H + Hkv) * D + s * Hkvl,
                         (H + Hkv) * D + (s + 1) * Hkvl))
    return np.asarray(idx, np.int32)


def tp_gated_ffn_permutation(F: int, shards: int) -> np.ndarray:
    """Column permutation taking [gate|up] to per-shard [gate_s|up_s]."""
    if F % shards:
        raise ValueError(f"d_ff={F} not divisible by {shards} shards")
    Fl = F // shards
    idx = []
    for s in range(shards):
        idx.extend(range(s * Fl, (s + 1) * Fl))
        idx.extend(range(F + s * Fl, F + (s + 1) * Fl))
    return np.asarray(idx, np.int32)


def tp_permute_qkv(w, H: int, Hkv: int, D: int, shards: int):
    """Shard-major column order for a fused QKV weight (last axis; works
    for layer-stacked ``(L, d, N)`` leaves too)."""
    import jax.numpy as jnp
    return jnp.take(w, tp_qkv_permutation(H, Hkv, D, shards), axis=-1)


def tp_permute_gated_ffn(w, F: int, shards: int):
    """Per-shard [gate_s|up_s] column order for a gated FFN in-projection."""
    import jax.numpy as jnp
    return jnp.take(w, tp_gated_ffn_permutation(F, shards), axis=-1)


def tp_param_pspec(name: str, ndim: int, axis: str = "model") -> P:
    """PartitionSpec for one serve param leaf under head-sharded TP.
    ``name`` is the leaf's key in the param tree; anything not explicitly
    sharded (norm scales, embeddings, the head) replicates."""
    if name in _TP_COL_SHARDED:
        return P(*([None] * (ndim - 1) + [axis]))
    if name in _TP_ROW_SHARDED:
        return P(*([None] * (ndim - 2) + [axis, None]))
    return P()


def tp_cache_pspec(name: str, ndim: int, axis: str = "model") -> P:
    """PartitionSpec for a KV-cache leaf: k/v shard their head axis (-2,
    both for contiguous ``(B,S,Hkv,D)`` / stacked ``(L,B,S,Hkv,D)`` leaves
    and for the paged ``(blocks,bs,Hkv,D)`` arena); positions and block
    tables replicate — the per-slot ``(B,)`` position contract and the
    slot manager are shard-invariant."""
    if name in ("k", "v"):
        return P(*([None] * (ndim - 2) + [axis, None]))
    return P()
