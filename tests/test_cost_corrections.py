"""Fitted cost-model corrections: the per-op-class table distilled from
the CI benchmark trajectory (``repro.tools fit-cost``) multiplies each
op's roofline terms — clamped, median-of-history, and OFF by default
(with no table loaded the model is bit-identical to the analytic one)."""
import json

import pytest

from repro import tools
from repro.core import cost_model as cm
from repro.core.op_spec import OpSpec


@pytest.fixture(autouse=True)
def _clean_table():
    """Every test starts and ends with no correction table installed and
    the env-load latch reset (so REPRO_COST_CORRECTIONS tests can probe
    the lazy path)."""
    cm._corrections = None
    cm._corrections_env_loaded = False
    yield
    cm._corrections = None
    cm._corrections_env_loaded = False


def _op(name, flops=4e9, hbm=2e9, grid=8):
    return OpSpec(name=name, grid=grid, body=None, inputs=(), outputs=(),
                  flops=flops, hbm_bytes=hbm)


# ---------------------------------------------------------------------------
# op_class: shape/index parameters stripped, classes stable across shapes
# ---------------------------------------------------------------------------
def test_op_class_strips_shape_params():
    assert cm.op_class("decode_attn_B2_S128_H4kv4") == "decode_attn"
    assert cm.op_class("decode_attn_B3_S256_H8kv2") == "decode_attn"
    assert cm.op_class("prefill_attn0_C8_S128_H4kv4_pg16") == "prefill_attn"
    assert cm.op_class("prefill_attn1_C16_S128_H1kv1") == "prefill_attn"
    assert cm.op_class("matmul_2x64x256") == "matmul"
    assert cm.op_class("rmsnorm_256x64") == "rmsnorm"
    assert cm.op_class("adamw_t0_256x128") == "adamw"
    # index-suffixed serve ops merge (norm1/norm2 share one class)
    assert cm.op_class("decode_norm1") == cm.op_class("decode_norm2") \
        == "decode_norm"
    # paper-suite names survive untouched (no parameter segments)
    for n in ("maxpool", "upsample", "bnstats", "hist", "ethash_like",
              "sha_like", "blake_like", "blake2b_like", "qkv_proj",
              "ffn_proj", "decode_act"):
        assert cm.op_class(n) == n
    # a name that normalizes to nothing falls back to itself
    assert cm.op_class("im2col") == "im2col"


def test_op_class_chains_normalize_per_member():
    chain = "decode_norm1" + "→" + "qkv_proj"
    assert cm.op_class(chain) == "decode_norm→qkv_proj"
    assert cm.op_class("ffn_proj→decode_act") == "ffn_proj→decode_act"


# ---------------------------------------------------------------------------
# default OFF: no table -> factor 1.0 -> analytic model unchanged
# ---------------------------------------------------------------------------
def test_default_off_is_identity(monkeypatch):
    monkeypatch.delenv("REPRO_COST_CORRECTIONS", raising=False)
    assert cm.correction_for("decode_attn_B2_S128_H4kv4") == 1.0
    op = _op("decode_attn_B2_S128_H4kv4")
    ramp = (op.t_compute + op.t_memory) / op.grid
    assert cm.native_time(op) == max(op.t_compute, op.t_memory) + ramp \
        + cm.LAUNCH_S


def test_corrections_scale_native_and_fused_times():
    a, b = _op("decode_attn_B2_S128_H4kv4", flops=1e9, hbm=8e9), \
        _op("qkv_proj", flops=8e9, hbm=1e9)
    base_a = cm.native_time(a)
    base_fused = cm.hfused_cost((a, b), cm.Schedule(1, 1)).t_hfused
    cm.set_corrections({"classes": {"decode_attn": {"correction": 1.5}}})
    # native: the roofline+ramp part scales, the launch constant does not
    assert cm.native_time(a) == pytest.approx(
        (base_a - cm.LAUNCH_S) * 1.5 + cm.LAUNCH_S)
    assert cm.native_time(b) == pytest.approx(cm.native_time(b))
    # fused: the corrected member's engine terms grow, so the bundle slows
    assert cm.hfused_cost((a, b), cm.Schedule(1, 1)).t_hfused > base_fused


def test_correction_clamped_on_lookup():
    cm.set_corrections({"wild_low": 0.01, "wild_high": 50.0, "mild": 1.2})
    lo, hi = cm.CORRECTION_CLAMP
    assert cm.correction_for("wild_low") == lo
    assert cm.correction_for("wild_high") == hi
    assert cm.correction_for("mild") == pytest.approx(1.2)
    assert cm.correction_for("unknown_class") == 1.0


def test_env_path_loads_table_lazily(tmp_path, monkeypatch):
    p = tmp_path / "corr.json"
    p.write_text(json.dumps(
        {"classes": {"decode_attn": {"correction": 1.25, "n": 3}}}))
    monkeypatch.setenv("REPRO_COST_CORRECTIONS", str(p))
    assert cm.correction_for("decode_attn_B9_S128_H2kv2") == 1.25
    # a broken path degrades to the analytic model, never raises
    cm._corrections = None
    cm._corrections_env_loaded = False
    monkeypatch.setenv("REPRO_COST_CORRECTIONS", str(tmp_path / "nope.json"))
    assert cm.correction_for("decode_attn_B9_S128_H2kv2") == 1.0


# ---------------------------------------------------------------------------
# the fit-cost tool: history files -> clamped median table -> loadable
# ---------------------------------------------------------------------------
def _history(tmp_path, reports):
    d = tmp_path / "history"
    d.mkdir()
    for i, rows in enumerate(reports):
        (d / f"BENCH_measured_interpret_{i:08x}.json").write_text(
            json.dumps({"backend": "interpret", "rows": rows}))
    (d / "BENCH_executed_interpret_deadbeef.json").write_text(
        json.dumps({"rows": [{"bundle": "ignored",
                              "fused_launches": 2}]}))   # no delta: skipped
    return d


def test_fit_cost_fits_clamped_medians(tmp_path, capsys):
    hist = _history(tmp_path, [
        [{"bundle": "maxpool+upsample+sha_like",
          "cm_vs_measured_delta_pct": 20.0},
         {"bundle": "ethash_like+hist", "cm_vs_measured_delta_pct": -80.0}],
        [{"bundle": "maxpool+upsample+sha_like",
          "cm_vs_measured_delta_pct": 40.0},
         {"bundle": "maxpool+hist", "cm_vs_measured_delta_pct": None}],
    ])
    out = tmp_path / "corr.json"
    rc = tools.main(["fit-cost", "--history", str(hist),
                     "--out", str(out), "--json"])
    assert rc == 0
    table = json.loads(out.read_text())
    assert table == json.loads(capsys.readouterr().out)
    # maxpool saw deltas (20, 40): median 30% -> x1.3
    assert table["classes"]["maxpool"]["correction"] == pytest.approx(1.3)
    assert table["classes"]["maxpool"]["n"] == 2
    assert table["classes"]["sha_like"]["correction"] == pytest.approx(1.3)
    # -80% would be x0.2: clamped to the floor
    assert table["classes"]["ethash_like"]["correction"] == \
        cm.CORRECTION_CLAMP[0]
    # the None-delta row and the executed-report file contributed nothing
    assert "ignored" not in table["classes"]
    assert table["rows"] == 3
    # the written table is exactly what set_corrections accepts
    cm.set_corrections(table)
    assert cm.correction_for("maxpool") == pytest.approx(1.3)


def test_fit_cost_empty_history_yields_inert_table(tmp_path):
    hist = tmp_path / "empty"
    hist.mkdir()
    out = tmp_path / "corr.json"
    assert tools.main(["fit-cost", "--history", str(hist),
                       "--out", str(out)]) == 0
    table = json.loads(out.read_text())
    assert table["classes"] == {} and table["rows"] == 0
    cm.set_corrections(table)
    assert cm.correction_for("anything") == 1.0
