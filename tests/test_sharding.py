"""Sharding-rule resolution properties (hypothesis) + an 8-fake-device
mini dry-run in a subprocess (train + decode compile on a (2,2,2) pod mesh,
incl. the int8 pod-compressed gradient path)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (see "
                           "requirements.txt)")
from hypothesis import given, settings, strategies as st

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# resolve_pspec properties (no devices needed beyond 1)
# ---------------------------------------------------------------------------
def _mesh_1d():
    import jax
    return jax.make_mesh((1,), ("data",))


def test_resolve_drops_nondivisible():
    import jax
    from repro.distributed.sharding import resolve_pspec
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 8}

    spec = resolve_pspec(("batch", "ffn"), (6, 64), FakeMesh(),
                         {"batch": "data", "ffn": "model"})
    assert spec[0] is None          # 6 % 4 != 0 -> replicated
    assert spec[1] == "model"       # 64 % 8 == 0


@settings(max_examples=40, deadline=None)
@given(b=st.integers(1, 64), f=st.integers(1, 128),
       data=st.sampled_from([2, 4, 8]), model=st.sampled_from([2, 8, 16]))
def test_resolve_never_overassigns(b, f, data, model):
    from repro.distributed.sharding import resolve_pspec

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": data, "model": model}

    spec = resolve_pspec(("batch", "ffn", "act_ffn"), (b, f, f), FakeMesh(),
                         {"batch": "data", "ffn": "model",
                          "act_ffn": "model"})
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else [s])
    assert len(flat) == len(set(flat))            # no axis used twice
    for name, dim in zip(spec, (b, f, f)):
        if name == "data":
            assert dim % data == 0
        if name == "model":
            assert dim % model == 0


def test_pod_rules_remap():
    from repro.distributed.sharding import rules_for
    from repro.configs import get_config

    class PodMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 2, "model": 2}

    rules = rules_for(get_config("granite-3-2b"), PodMesh())
    assert rules["batch"] == ("pod", "data")
    rules_ds = rules_for(get_config("deepseek-v2-236b"), PodMesh())
    assert rules_ds["expert"] == ("pod", "data")  # moe-huge FSDP experts


# ---------------------------------------------------------------------------
# subprocess mini dry-run on 8 fake devices
# ---------------------------------------------------------------------------
MINI = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_debug_mesh
    from repro.launch import input_specs as ispecs
    from repro.launch.dryrun import build_cell
    from repro.configs.base import ShapeConfig
    from repro.distributed.hlo_analysis import analyze_compiled

    mesh = make_debug_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config({arch!r}).reduced()
    shape = ShapeConfig("t", 64, 8, {kind!r})
    rules = shd.rules_for(cfg, mesh)
    with shd.use_sharding(mesh, rules):
        fn, args, in_sh, donate = build_cell(cfg, shape, mesh)
        compiled = jax.jit(fn, in_shardings=in_sh,
                           donate_argnums=donate).lower(*args).compile()
    r = analyze_compiled(compiled, mesh.size)
    print("RESULT", r.flops > 0, r.coll_bytes >= 0)
""")


@pytest.mark.parametrize("arch,kind", [
    ("granite-3-2b", "train"),
    ("deepseek-v2-236b", "train"),
    ("recurrentgemma-2b", "decode"),
    ("phi3.5-moe-42b-a6.6b", "decode"),
    ("xlstm-1.3b", "prefill"),
])
def test_mini_multipod_compile(arch, kind):
    code = MINI.format(src=SRC, arch=arch, kind=kind)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert "RESULT True True" in out.stdout, out.stderr[-3000:]


def test_pod_compressed_grads_compile():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.dryrun import build_cell
        from repro.configs.base import ShapeConfig
        from repro.models import lm
        from repro.models.base import abstract_params, logical_axes
        from repro.train.train_loop import TrainConfig, make_train_step
        from repro.train import optimizer as opt_mod

        mesh = make_debug_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("granite-3-2b").reduced()
        rules = shd.rules_for(cfg, mesh)
        specs = lm.param_specs(cfg)
        pa = abstract_params(specs, jnp.bfloat16)
        ps = shd.sharding_tree(pa, logical_axes(specs), mesh, rules)
        tcfg = TrainConfig(remat=False, compression="int8_pod")
        step = make_train_step(cfg, tcfg, mesh)
        oa = opt_mod.OptState(
            m=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), pa),
            v=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), pa),
            count=jax.ShapeDtypeStruct((), jnp.int32))
        batch = {{"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}}
        with shd.use_sharding(mesh, rules):
            c = jax.jit(step).lower(pa, oa, batch,
                                    jax.ShapeDtypeStruct((), jnp.int32)).compile()
        txt = c.as_text()
        assert "s8" in txt, "int8 not on the wire"
        print("RESULT OK")
    """).format(src=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert "RESULT OK" in out.stdout, out.stderr[-3000:]


def test_collective_parser():
    from repro.distributed.hlo_analysis import collective_bytes
    hlo = """
      %all-reduce.1 = f32[1024,256]{1,0} all-reduce(%x), replica_groups=[16,2]<=[32]
      %ag = bf16[64,128]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}
      %cp-start = (f32[128]{0}) collective-permute-start(%z)
      %noise = f32[8]{0} add(%a, %b)
    """
    st = collective_bytes(hlo, 32)
    assert st.by_kind["all-reduce"] == pytest.approx(2 * 1024 * 256 * 4 * 0.5)
    assert st.by_kind["all-gather"] == pytest.approx(64 * 128 * 2 * 0.75)
    assert st.count == 3
