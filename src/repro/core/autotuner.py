"""The paper's Main() search (Fig. 6), adapted and generalized to bundles:

  paper                                  here
  -------------------------------------  -----------------------------------
  d1 <- 128, 256, ... (thread partition) Schedule ratio vectors (r_0:..:r_N)
  profile F without register bound       cost under full VMEM budget
  compute r0, profile F with bound r0    cost under the computed VMEM cap
                                         (shrunk block variants if provided)
  keep the fastest (F*, r*)              keep (schedule*, variant*, cap*)

Scoring: the three-term roofline cost model by default; on real TPU hardware
pass ``measure=`` (a wall-clock callable) and the loop becomes the paper's
measurement-driven profiling verbatim.  Every candidate is recorded in the
search log (EXPERIMENTS.md shows these for the fig7 pairs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from repro.core import hfuse
from repro.core.cost_model import (VMEM_BUDGET, FusedEstimate, Schedule,
                                   hfused_cost, ratio_candidates)
from repro.core.op_spec import OpSpec


@dataclass
class Candidate:
    sched: Schedule
    variant: int                  # index into the bundle-variant list
    vmem_cap: Optional[int]
    est: FusedEstimate
    measured_s: Optional[float] = None

    @property
    def score(self) -> float:
        return self.measured_s if self.measured_s is not None else self.est.t_hfused


@dataclass
class SearchResult:
    best: Candidate
    log: list[Candidate]
    ops: tuple[OpSpec, ...]

    # 2-op compatibility accessors
    @property
    def a(self) -> OpSpec:
        return self.ops[0]

    @property
    def b(self) -> OpSpec:
        return self.ops[1]

    def build(self, *, interpret: bool = False):
        return hfuse.generate(self.ops, self.best.sched, interpret=interpret,
                              vmem_limit=self.best.vmem_cap)

    def table(self) -> list[dict]:
        return [{
            "sched": c.sched.label(), "variant": c.variant,
            "vmem_cap": c.vmem_cap, "t_hfused_us": c.est.t_hfused * 1e6,
            "speedup_pct": c.est.speedup_pct(), "vmem_ok": c.est.vmem_ok,
            "measured_s": c.measured_s,
        } for c in self.log]


def _as_variants(variants) -> list[tuple[OpSpec, ...]]:
    """One bundle (sequence of OpSpecs) or a list of bundle variants."""
    variants = list(variants)
    if variants and isinstance(variants[0], OpSpec):
        return [tuple(variants)]
    return [tuple(v) for v in variants]


def search(variants: Sequence, *, vmem_budget: int = VMEM_BUDGET,
           measure: Optional[Callable] = None) -> SearchResult:
    """Search schedules × bundle variants × VMEM caps.

    ``variants``: one bundle — ``(opA, opB)`` or ``(op1, .., opN)`` — or a
    list of alternative bundles (e.g. alternative block shapes — the
    register-cap analogue shrinks blocks to restore pipelining headroom).
    """
    variants = _as_variants(variants)
    log: list[Candidate] = []
    best: Optional[Candidate] = None
    best_ops: Optional[tuple[OpSpec, ...]] = None
    for vi, ops in enumerate(variants):
        for sched in ratio_candidates(ops):
            # "no register bound": full budget
            caps = [None]
            # "with bound r0": the budget the bundle would need to co-reside
            # with full double buffering (paper Fig. 6 line 13-16 analogue)
            need = 2 * sum(op.vmem_bytes for op in ops)
            if need > vmem_budget:
                caps.append(vmem_budget)
            for cap in caps:
                est = hfused_cost(ops, sched,
                                  vmem_budget=cap or vmem_budget)
                cand = Candidate(sched, vi, cap, est)
                if measure is not None:
                    fused = hfuse.generate(ops, sched, vmem_limit=cap)
                    cand.measured_s = measure(fused, *ops)
                log.append(cand)
                if best is None or cand.score < best.score:
                    best = cand
                    best_ops = ops
    return SearchResult(best=best, log=log, ops=best_ops)
