"""OpSpec — the fusible-kernel IR of the horizontal-fusion engine.

An OpSpec is the TPU analogue of the paper's "input kernel": a computation
with a linear (1-D) grid of independent steps, per-operand BlockSpecs, and a
resource profile (FLOPs / HBM bytes / VMEM working set).  The paper's kernels
are CUDA source; ours are Pallas bodies.  The 1-D grid plays the role of the
block space; the *fused* kernel's grid (core/hfuse.py) partitions / interleaves
its steps between two ops the way HFUSE partitions the thread space.

Contract for ``body``:
  body(step, *in_refs, *out_refs) — ``step`` is the op-local grid step
  (a traced scalar); refs are VMEM blocks selected by the index maps.
  The body must not call pl.program_id itself (the fused kernel owns it).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.distributed.hlo_analysis import HBM_BW, PEAK_FLOPS, RIDGE, VMEM_BYTES


@dataclass(frozen=True)
class Operand:
    """One input or output of a fusible op."""
    shape: tuple[int, ...]
    dtype: Any
    block_shape: tuple[int, ...]
    index_map: Callable[[Any], tuple]      # op-local step -> block indices

    def block_bytes(self) -> int:
        return int(math.prod(self.block_shape)) * jnp.dtype(self.dtype).itemsize


@dataclass
class OpSpec:
    name: str
    grid: int                              # number of op-local steps
    body: Callable                         # body(step, *in_refs, *out_refs)
    inputs: tuple[Operand, ...]
    outputs: tuple[Operand, ...]
    flops: float                           # whole-op FLOPs
    hbm_bytes: float                       # whole-op HBM traffic (streaming)
    tag: str = ""                          # provenance (paper-suite name etc.)
    shrink: Optional[Callable] = None      # factor -> OpSpec with smaller
    #                                        blocks (overrides shrink_blocks'
    #                                        structural rewrite)
    # Epilogue contract (core/stitch.py): declaring ``epilogue=(consumer,
    # operand)`` on a producer asserts its single output feeds EXACTLY that
    # consumer's named operand and is dead afterwards — the planner may then
    # contract the pair into one stitched chain whose intermediate never
    # round-trips HBM.  ``chain`` marks an OpSpec that IS such a chain (the
    # member names, producer first); ``extra_vmem_bytes`` accounts for the
    # register/VMEM-resident intermediate the stitch keeps live per step.
    epilogue: Optional[tuple[str, str]] = None
    chain: tuple[str, ...] = ()
    extra_vmem_bytes: int = 0
    # Stable operand signature (core/binding.py contract): one name per
    # input/output, positional order.  An op with names can be bound to live
    # arrays by the executor; unnamed operands are tuning-only.  A name may
    # appear in BOTH tuples (in-place semantics: adamw's p/m/v) — the
    # binding then reads and rewrites the same state key.
    in_names: tuple[str, ...] = ()
    out_names: tuple[str, ...] = ()

    def __post_init__(self):
        if self.in_names and len(self.in_names) != len(self.inputs):
            raise ValueError(f"{self.name}: {len(self.in_names)} in_names "
                             f"for {len(self.inputs)} inputs")
        if self.out_names and len(self.out_names) != len(self.outputs):
            raise ValueError(f"{self.name}: {len(self.out_names)} out_names "
                             f"for {len(self.outputs)} outputs")

    @property
    def has_signature(self) -> bool:
        return bool(self.in_names) and bool(self.out_names)

    # ------------------------------------------------------------------
    @property
    def vmem_bytes(self) -> int:
        """Per-step working set (single-buffered); a stitched chain's
        resident intermediate rides in ``extra_vmem_bytes``."""
        return (sum(o.block_bytes() for o in (*self.inputs, *self.outputs))
                + self.extra_vmem_bytes)

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)

    @property
    def bound(self) -> str:
        """Roofline classification — the paper's 'kind of GPU resource'."""
        return "compute" if self.arithmetic_intensity >= RIDGE else "memory"

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_native(self) -> float:
        """Ideal pipelined standalone time: max of the two engine terms."""
        return max(self.t_compute, self.t_memory)

    def step_costs(self) -> tuple[float, float]:
        """(compute, memory) seconds per grid step (uniform-step assumption)."""
        return self.t_compute / self.grid, self.t_memory / self.grid

    def describe(self) -> dict:
        return {
            "name": self.name, "grid": self.grid, "flops": self.flops,
            "hbm_bytes": self.hbm_bytes, "vmem_bytes": self.vmem_bytes,
            "arithmetic_intensity": round(self.arithmetic_intensity, 2),
            "bound": self.bound,
            "t_compute_us": self.t_compute * 1e6,
            "t_memory_us": self.t_memory * 1e6,
            "t_native_us": self.t_native * 1e6,
        }


def make_operand(arr_or_sds, block_shape, index_map) -> Operand:
    return Operand(tuple(arr_or_sds.shape), arr_or_sds.dtype,
                   tuple(block_shape), index_map)


# ---------------------------------------------------------------------------
# Automatic block shrinking (the paper's register-cap analogue)
# ---------------------------------------------------------------------------
MIN_BLOCK_ROWS = 8                # TPU sublane floor (f32 tile is (8, 128))


def _index_pattern(operand: Operand, grid: int = 8) -> Optional[str]:
    """Classify an index map by probing it with concrete steps sampled
    across the whole ``grid``.

    'const'  — same block every step (broadcast operand: weights, carries).
    'stream' — unit-stride in the leading axis, (s, c1, ..) with the other
               components constant: the row-partitioned streaming pattern
               every shrinkable op in this repo uses.
    None     — anything else (opaque/affine maps): not safely rewritable.

    The probe sample must include late steps: batch-major maps like
    ``s // nk`` (decode attention's per-slot operands) are constant over
    the first ``nk`` steps and would masquerade as 'const' under a probe
    of small steps only — misclassifying a streamed operand as a
    broadcast would let ``shrink_blocks`` silently break the body's slot
    addressing.  Probing {grid//2, grid-1} alongside {0, 1, 2} rules that
    out for every monotone map at any ``nk``; the small steps are probed
    even past a tiny grid (pure extrapolation) so grid-1 streaming ops
    still classify as 'stream' and keep their halved-block variant.
    """
    steps = sorted({0, 1, 2, grid // 2, max(grid - 1, 0)})
    try:
        probes = {s: tuple(int(c) for c in operand.index_map(s))
                  for s in steps}
    except Exception:
        return None
    first = probes[0]
    if all(p == first for p in probes.values()):
        return "const"
    if (all(p[0] == s for s, p in probes.items())
            and all(p[1:] == first[1:] for p in probes.values())):
        return "stream"
    return None


def shrink_blocks(op: OpSpec, factor: int = 2) -> Optional[OpSpec]:
    """Halve (``factor=2``) every streamed operand's leading block dim and
    scale the grid to match — the working set shrinks x``factor``, total
    work is unchanged.  This is the paper's Fig. 6 register-bound move
    (maxrregcount r0) translated to VMEM: when a fused bundle can't
    co-reside double-buffered, smaller blocks restore pipelining headroom.

    Returns None when the rewrite can't be proven safe:
      * an op-provided ``shrink`` factory takes precedence (exact rewrite);
      * every operand must classify as 'const' or unit-stride 'stream';
      * streamed leading dims must divide by ``factor`` and stay >= the
        sublane floor;
      * a const operand whose block shares a streamed leading dim is
        assumed shape-coupled to the stream inside the body (e.g.
        ethash's seed block is added elementwise to the DAG block) —
        shrinking one side would break the body.
    """
    if factor <= 1:
        return op
    if op.shrink is not None:
        return op.shrink(factor)

    operands = (*op.inputs, *op.outputs)
    patterns = [_index_pattern(o, op.grid) for o in operands]
    if any(p is None for p in patterns):
        return None
    stream_leads = {o.block_shape[0]
                    for o, p in zip(operands, patterns) if p == "stream"}
    if not stream_leads:
        return None                           # nothing streams: nothing to shrink
    for o, p in zip(operands, patterns):
        if p == "stream":
            lead = o.block_shape[0]
            if lead % factor or lead // factor < MIN_BLOCK_ROWS:
                return None
        elif any(d in stream_leads for d in o.block_shape):
            return None                       # body-coupled const operand

    def shrunk(o: Operand, p: str) -> Operand:
        if p == "const":
            return o
        return dataclasses.replace(
            o, block_shape=(o.block_shape[0] // factor, *o.block_shape[1:]))

    n_in = len(op.inputs)
    new = [shrunk(o, p) for o, p in zip(operands, patterns)]
    return dataclasses.replace(
        op, grid=op.grid * factor,
        inputs=tuple(new[:n_in]), outputs=tuple(new[n_in:]),
        tag=f"{op.tag}|blocks/{factor}" if op.tag else f"blocks/{factor}")
