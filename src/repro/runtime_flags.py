"""Global runtime flags.

UNROLL_SCANS — when True, layer stacks / attention KV loops / mLSTM chunk
loops run as unrolled Python loops instead of lax.scan.  Used by the dry-run:
XLA's HLO cost analysis counts a `while` body ONCE (it has no trip-count
model), so scanned programs undercount FLOPs/bytes/collective-bytes by the
trip count.  Unrolled lowering costs compile time but yields exact
whole-program cost_analysis numbers for §Roofline.

Strictly-sequential recurrences (sLSTM over S=4096 steps) are never unrolled;
their contribution is analytically small (<5% of any assigned cell) and the
undercount is documented in EXPERIMENTS.md §Methodology.
"""
UNROLL_SCANS = False


def maybe_scan(body, carry, xs, length=None):
    """lax.scan, or an unrolled Python loop when UNROLL_SCANS is set.

    body(carry, x) -> (carry, y).  xs: pytree with leading axis, or None.
    Returns (carry, ys) with ys stacked (or None if all ys are None).
    """
    import jax
    import jax.numpy as jnp

    if not UNROLL_SCANS:
        return jax.lax.scan(body, carry, xs, length=length)

    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
