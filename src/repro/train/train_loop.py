"""Train step assembly: autodiff, microbatched gradient accumulation,
optional int8 pod-axis gradient compression, AdamW update, metrics.

The returned ``train_step`` is pure — (params, opt_state, batch, step) ->
(params, opt_state, metrics) — and is jitted/lowered by the caller with
explicit shardings (see launch/dryrun.py, launch/train.py).

Distributed-optimization notes (DESIGN.md §7):
  * grad accumulation is a ``lax.scan`` over microbatches — XLA's
    latency-hiding scheduler overlaps microbatch i's gradient all-reduce
    with microbatch i+1's backward compute;
  * with ``compression='int8_pod'`` the inter-pod reduction goes through
    repro.distributed.compression (int8 on the slow links);
  * ``zero=True`` shards optimizer moments over the data axis (ZeRO-1):
    XLA turns the gradient all-reduce into reduce-scatter + the param
    update all-gather.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.train import optimizer as opt_mod
from repro.train.optimizer import AdamWConfig, OptState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    grad_accum: int = 1
    remat: bool = True
    compression: Optional[str] = None       # None | 'int8_pod'
    zero: bool = False                      # ZeRO-1 optimizer-state sharding
    max_grad_norm: float = 1.0


def leaf_update_name(path) -> str:
    """Stable graph-op name stem for one param leaf — the ONE place leaf
    paths become op names (plan, bindings, and state keys all share it)."""
    return "".join(c if c.isalnum() else "_"
                   for c in jax.tree_util.keystr(path)).strip("_")


def _leaf_rows(leaf, bm: int):
    """(n, R, bm_i): flat element count, padded (R, 128) rows, block rows —
    the layout contract shared by kernels.adam._flatten_leaf and the
    adamw OpSpec grid."""
    import math

    from repro.kernels.adam import LANES

    n = math.prod(leaf.shape) if leaf.shape else 1
    rows = math.ceil(n / LANES)
    bm_i = min(bm, rows)
    R = math.ceil(rows / bm_i) * bm_i
    return n, R, bm_i


def update_graph(params, *, tokens: int = 4096, bm: int = 1024,
                 max_tensors: Optional[int] = 8, include_dW: bool = True,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 wd: float = 0.1):
    """The optimizer-step op graph: one AdamW-update OpSpec per param leaf
    (stable operand signature: scalars/p/g/m/v -> p/m/v) and, with
    ``include_dW``, the backward dW matmul ``x^T @ g`` each 2-D parameter's
    update *depends on* (an update can never fuse *horizontally* with the
    matmul producing its gradient, but rides another tensor's).  When the
    dW output's row-major layout lines up exactly with the update's padded
    (R, 128) gradient view, the dW op declares the update as its *epilogue*
    (core/stitch.py) — the planner contracts the pair into one
    ``dW_w→adamw_w`` member whose gradient never round-trips HBM, and that
    chain still fuses horizontally with other tensors' updates.

    Returns ``(graph, layout)``: the planner graph plus the per-leaf layout
    ``[(name, path, n, R, bm_i), ...]`` the executor's pack/unpack uses —
    names are derived once here, not re-derived ad hoc by callers.
    """
    import math

    from repro.core import planner
    from repro.kernels.adam import LANES, adamw_op
    from repro.kernels.matmul import matmul_1d_op

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    if max_tensors is not None:
        flat = sorted(flat, key=lambda kv: -math.prod(kv[1].shape or (1,)))
        flat = flat[:max_tensors]
    graph: list[planner.GraphOp] = []
    layout: list[tuple] = []
    for path, leaf in flat:
        pname = leaf_update_name(path)
        n, R, bm_i = _leaf_rows(leaf, bm)
        deps: frozenset[str] = frozenset()
        if include_dW and leaf.ndim == 2:
            d_in, d_out = leaf.shape
            bmm = min(256, d_in)
            if d_in % bmm == 0:
                dw = matmul_1d_op(M=d_in, K=tokens, N=d_out, dtype=leaf.dtype,
                                  bm=bmm)
                dw = dataclasses.replace(dw, name=f"dW_{pname}",
                                         tag="train:dW")
                if n % LANES == 0 and (bmm * d_out) % LANES == 0:
                    # exact row-major correspondence: (d_in, d_out) flattens
                    # to (n/128, 128) with no padding, and matching the
                    # update's block rows to dW's block (bmm rows of d_out)
                    # makes the two grids identical — can_stitch's
                    # row-stream case, so dW can hand the update its
                    # gradient block in-register
                    bm_i = bmm * d_out // LANES
                    R = n // LANES
                    dw = dataclasses.replace(
                        dw, epilogue=(f"adamw_{pname}", "g"))
                graph.append(planner.GraphOp(dw))
                deps = frozenset({dw.name})
        upd = adamw_op(R=R, dtype=leaf.dtype, bm=bm_i, name=f"adamw_{pname}",
                       b1=b1, b2=b2, eps=eps, wd=wd)
        graph.append(planner.GraphOp(upd, deps=deps))
        layout.append((f"adamw_{pname}", path, n, R, bm_i))
    return graph, layout


def plan_update_fusion(params, *, tokens: int = 4096, max_ways: int = 3,
                       bm: int = 1024, max_tensors: int = 8,
                       measure=None, cache=None):
    """Hand the optimizer's per-tensor update OpSpecs plus the backward dW
    matmuls to ``planner.plan(max_ways>=3)`` — optimizer/backward overlap is
    *planned*, not hand-wired (ROADMAP; docs/nway_fusion.md).

    ``measure``/``cache`` flow through to the autotuner, so schedules are
    profiled once (core/timing) and reused forever (core/schedule_cache).
    Largest ``max_tensors`` parameters only — the tail adds launches the
    multi-tensor Adam path already amortizes.
    """
    from repro.core import planner

    graph, _ = update_graph(params, tokens=tokens, bm=bm,
                            max_tensors=max_tensors, include_dW=True)
    return planner.plan(graph, max_ways=max_ways, measure=measure,
                        cache=cache)


class UpdateProgram:
    """The executed optimizer step: a ``FusionPlan`` over every param
    leaf's AdamW op, lowered by ``core/executor`` — fused bundles run via
    ``SearchResult.build()``, leftovers via ``run_single`` — with the
    binding registry routing each op's operands to the flattened (R, 128)
    views of its param/grad/moment leaves.  This is the planner-driven
    generalization of ``kernels.adam.multi_tensor_adamw`` (the parity
    baseline in tests/test_executor.py)."""

    def __init__(self, plan, program, layout, hyper: dict):
        self.plan = plan
        self.program = program
        self.layout = layout
        self.hyper = hyper

    def __call__(self, params, grads, m, v, *, lr, bc1, bc2):
        from repro.kernels.adam import LANES, _flatten_leaf, _unflatten_leaf

        leaves_p, treedef = jax.tree_util.tree_flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_m = treedef.flatten_up_to(m)
        leaves_v = treedef.flatten_up_to(v)
        scalars = jnp.zeros((1, LANES), jnp.float32)
        scalars = scalars.at[0, 0].set(lr).at[0, 1].set(bc1).at[0, 2].set(bc2)

        state = {"scalars": scalars}
        for (name, _path, n, R, bm_i), lp, lg, lm, lv in zip(
                self.layout, leaves_p, leaves_g, leaves_m, leaves_v):
            state[f"{name}.p"], _ = _flatten_leaf(lp, row_multiple=bm_i)
            state[f"{name}.g"], _ = _flatten_leaf(lg.astype(lp.dtype),
                                                  row_multiple=bm_i)
            state[f"{name}.m"], _ = _flatten_leaf(lm.astype(jnp.float32),
                                                  row_multiple=bm_i)
            state[f"{name}.v"], _ = _flatten_leaf(lv.astype(jnp.float32),
                                                  row_multiple=bm_i)
        state = self.program(state)
        new_p, new_m, new_v = [], [], []
        for (name, _path, n, _R, _bm_i), lp, lm, lv in zip(
                self.layout, leaves_p, leaves_m, leaves_v):
            new_p.append(_unflatten_leaf(state[f"{name}.p"], n, lp))
            new_m.append(_unflatten_leaf(state[f"{name}.m"], n, lm))
            new_v.append(_unflatten_leaf(state[f"{name}.v"], n, lv))
        return (treedef.unflatten(new_p), treedef.unflatten(new_m),
                treedef.unflatten(new_v))

    def describe(self) -> list[dict]:
        return self.program.describe()


def build_update_program(params, ocfg: Optional[AdamWConfig] = None, *,
                         bm: int = 1024, max_ways: int = 4,
                         measure=None, cache=None,
                         interpret: Optional[bool] = None) -> UpdateProgram:
    """Plan + compile the executed optimizer step for ``params`` (live or
    abstract).  All leaves participate — the executed step must update the
    whole tree.  The dW matmuls are *planning-only* (their operands — the
    backward's activations — are autodiff internals the update step never
    sees live), so the executable graph holds the per-tensor update ops;
    they fuse with each other (``allow_same_bound``: all memory-bound, the
    gain is launch+ramp amortization — multi-tensor-apply rediscovered by
    the planner).
    """
    from repro.core import executor, planner
    from repro.core.binding import BindingRegistry

    ocfg = ocfg or AdamWConfig()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    graph, layout = update_graph(
        params, bm=bm, max_tensors=None, include_dW=False,
        b1=ocfg.b1, b2=ocfg.b2, eps=ocfg.eps, wd=ocfg.weight_decay)
    plan = planner.plan(graph, max_ways=max_ways, allow_same_bound=True,
                        measure=measure, cache=cache)
    reg = BindingRegistry()
    for name, *_ in layout:
        reg.bind(name, scalars="scalars", p=f"{name}.p", g=f"{name}.g",
                 m=f"{name}.m", v=f"{name}.v")
    program = executor.compile_plan(plan, bindings=reg, interpret=interpret)
    return UpdateProgram(plan, program, layout,
                         hyper=dict(b1=ocfg.b1, b2=ocfg.b2, eps=ocfg.eps,
                                    wd=ocfg.weight_decay))


def _split_microbatches(batch: dict, n: int) -> dict:
    def r(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree.map(r, batch)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype),
                        tree), norm


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None,
                    update_program: Optional[UpdateProgram] = None) -> Callable:
    """``update_program`` (train_loop.build_update_program) routes the
    optimizer step through the plan->program executor instead of the
    hand-wired update paths — the `--plan-fusion` hot path."""
    loss_fn = functools.partial(lm.loss_fn, cfg, remat=tcfg.remat)

    def loss_wrap(params, batch):
        return loss_fn(params, batch)

    if tcfg.compression == "int8_pod" and mesh is not None:
        from repro.distributed.compression import pod_compressed_grads
        grad_fn = pod_compressed_grads(lambda p, b: loss_wrap(p, b), mesh)
    else:
        def grad_fn(params, batch):
            (l, aux), g = jax.value_and_grad(loss_wrap, has_aux=True)(params, batch)
            return l, aux, g

    def compute_grads(params, batch):
        if tcfg.grad_accum <= 1:
            return grad_fn(params, batch)
        micro = _split_microbatches(batch, tcfg.grad_accum)

        def body(carry, mb):
            acc, lsum = carry
            l, aux, g = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, gi: a + gi.astype(jnp.float32), acc, g)
            return (acc, lsum + l), aux

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, lsum), auxs = jax.lax.scan(body, (acc0, 0.0), micro)
        g = jax.tree.map(lambda a: a / tcfg.grad_accum, acc)
        aux = jax.tree.map(lambda a: a[-1], auxs)
        return lsum / tcfg.grad_accum, aux, g

    def train_step(params, opt_state: OptState, batch, step):
        loss, aux, grads = compute_grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.max_grad_norm)
        new_params, new_opt = opt_mod.update(tcfg.optimizer, grads, opt_state,
                                             params, program=update_program)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt_mod.schedule(tcfg.optimizer, opt_state.count + 1)}
        if isinstance(aux, dict):
            metrics.update({k: v for k, v in aux.items()})
        return new_params, new_opt, metrics

    return train_step
