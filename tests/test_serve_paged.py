"""Paged KV-cache serve path (serve/kv_pool.py + the ``block_table=``
kernels): the executed continuous engine with ``paged_kv=True``.

Differential contract: the paged engine stays token-for-token identical
to the contiguous executed engine (itself pinned to the wavefront oracle)
on mixed-length traces and mid-batch EOS retirement — the block-table
indirection is pure data movement.  Capability contract: a shared-prefix
trace runs STRICTLY fewer prefill chunks at identical tokens (the prefix
cache skips whole chunks), and a prompt longer than ``max_len`` is served
once ``kv_slot_blocks`` raises the logical capacity — the per-engine
``max_len`` ceiling is gone.  Structural contract: the fused decode
launch carries the paged prefill chunk ⊕ paged decode attention, both
with the block table bound as a real operand ("bt" in in_names).
Plus: ``max_len`` immutability (``cache_len`` exposes the aligned/paged
capacity instead of mutating the user's value), constructor validation,
and graceful degradation when the arena is undersized."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import PrefillBudget, Request, ServeEngine

PG = dict(paged_kv=True, kv_block_size=16)
BUDGET = PrefillBudget(chunk_rows=8, max_coresident_chunks=2)
# chunk_rows=16 makes the effective chunk 16 on BOTH paths (contiguous
# and paged, whose chunk must be a block multiple) — chunk counts compare
# apples to apples in the shared-prefix test
BUDGET16 = PrefillBudget(chunk_rows=16, max_coresident_chunks=2)
LENS = (6, 15, 41, 9)
BUDGETS = (3, 4, 3, 2)


def _cfg():
    return dataclasses.replace(get_config("granite-3-2b").reduced(),
                               dtype="float32")


def _requests(cfg, lens, budgets, eos=None, prefix=0, seed=11):
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, cfg.vocab_size, prefix).astype(np.int32)
    return [Request(rid=i,
                    prompt=np.concatenate([
                        shared,
                        rng.integers(1, cfg.vocab_size, L).astype(np.int32)]),
                    max_new_tokens=m, eos_token=eos)
            for i, (L, m) in enumerate(zip(lens, budgets))]


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    contig = ServeEngine(cfg, params, batch=2, max_len=48,
                         scheduling="continuous", plan_fusion=True,
                         prefill_budget=BUDGET)
    paged = ServeEngine(cfg, params, batch=2, max_len=48,
                        scheduling="continuous", plan_fusion=True,
                        prefill_budget=BUDGET, **PG)
    assert contig.executed and paged.executed
    return cfg, params, contig, paged


# ---------------------------------------------------------------------------
# Constructor contract: max_len immutability, cache_len, validation
# ---------------------------------------------------------------------------
def test_max_len_stays_immutable_cache_len_exposes_capacity(setup):
    cfg, params, contig, paged = setup
    # the executed engine used to silently mutate max_len to the
    # 128-aligned cache size; now the user's value survives and the
    # aligned capacity lives in cache_len
    assert contig.max_len == 48 and contig.cache_len == 128
    assert paged.max_len == 48 and paged.cache_len == 128
    big = ServeEngine(cfg, params, batch=2, max_len=48,
                      scheduling="continuous", plan_fusion=True,
                      prefill_budget=BUDGET, kv_slot_blocks=16, **PG)
    assert big.max_len == 48 and big.cache_len == 256
    # non-executed engines never aligned: cache_len == max_len
    plain = ServeEngine(cfg, params, batch=2, max_len=48)
    assert plain.cache_len == plain.max_len == 48


def test_paged_constructor_validation(setup):
    cfg, params, _contig, _paged = setup
    with pytest.raises(ValueError, match="plan_fusion"):
        ServeEngine(cfg, params, batch=2, max_len=48, paged_kv=True)
    with pytest.raises(ValueError, match="must divide"):
        ServeEngine(cfg, params, batch=2, max_len=48,
                    scheduling="continuous", plan_fusion=True,
                    paged_kv=True, kv_block_size=12)
    with pytest.raises(ValueError, match="multiple of 128"):
        ServeEngine(cfg, params, batch=2, max_len=48,
                    scheduling="continuous", plan_fusion=True,
                    kv_slot_blocks=9, **PG)


# ---------------------------------------------------------------------------
# Structural: the fused launch binds the block table on both kernels
# ---------------------------------------------------------------------------
def test_fused_launch_carries_paged_ops_with_block_table(setup):
    _cfg_, _params, _contig, paged = setup
    graph = paged.decode_graph(prefill_chunks=1)
    att = [g.op for g in graph if g.op.name.startswith("decode_attn")]
    pf = [g.op for g in graph if g.op.name.startswith("prefill_attn")]
    assert att and pf
    for op in att + pf:
        assert op.name.endswith("_pg16"), op.name
        assert "bt" in op.in_names, (op.name, op.in_names)
    prog = paged.build_decode_program(prefill_chunks=1)
    mixed = [ms for ms in prog.fused_members
             if any(m.startswith("prefill_attn") for m in ms)
             and any(not m.startswith("prefill_attn") for m in ms)]
    assert mixed, f"paged chunk not fused with decode work: " \
                  f"{prog.fused_members}"


# ---------------------------------------------------------------------------
# Differential: paged == contiguous executed engine, token for token
# ---------------------------------------------------------------------------
def test_paged_matches_contiguous_mixed_lengths(setup):
    cfg, _params, contig, paged = setup
    rc = _requests(cfg, LENS, BUDGETS)
    rp = _requests(cfg, LENS, BUDGETS)
    contig.run(rc)
    paged.run(rp)
    assert [r.out_tokens for r in rp] == [r.out_tokens for r in rc]
    st = paged.stats
    assert st.blocks_in_use > 0
    assert st.fused_prefill_fraction > 0.0


def test_paged_matches_contiguous_mid_batch_eos(setup):
    cfg, _params, contig, paged = setup
    probe = _requests(cfg, LENS, BUDGETS)
    contig.run(probe)
    eos = probe[1].out_tokens[1]          # fires after 2 of its 4 tokens
    rc = _requests(cfg, LENS, BUDGETS, eos=eos)
    rp = _requests(cfg, LENS, BUDGETS, eos=eos)
    contig.run(rc)
    paged.run(rp)
    assert [r.out_tokens for r in rp] == [r.out_tokens for r in rc]
    assert any(reason == "eos" for _s, _r, reason in paged.stats.retirements)


# ---------------------------------------------------------------------------
# Capability: prefix cache drops whole chunks; max_len ceiling is gone
# ---------------------------------------------------------------------------
def test_shared_prefix_runs_strictly_fewer_chunks(setup):
    cfg, params, _contig, _paged = setup
    kw = dict(batch=2, max_len=64, scheduling="continuous",
              plan_fusion=True, prefill_budget=BUDGET16)
    contig = ServeEngine(cfg, params, **kw)
    paged = ServeEngine(cfg, params, **kw, **PG)
    lens, buds = (7, 9, 5, 11), (3, 3, 3, 3)
    rc = _requests(cfg, lens, buds, prefix=32)
    rp = _requests(cfg, lens, buds, prefix=32)
    contig.run(rc)
    paged.run(rp)
    assert [r.out_tokens for r in rp] == [r.out_tokens for r in rc]
    st = paged.stats
    assert st.prefill_chunks < contig.stats.prefill_chunks, \
        (st.prefill_chunks, contig.stats.prefill_chunks)
    assert st.prefix_hits >= 2 and st.prefix_hit_rate > 0
    assert st.prefix_tokens_reused >= 2 * 32


def test_prefix_cache_survives_across_runs(setup):
    cfg, params, _contig, _paged = setup
    eng = ServeEngine(cfg, params, batch=2, max_len=64,
                      scheduling="continuous", plan_fusion=True,
                      prefill_budget=BUDGET16, **PG)
    lens, buds = (7, 9), (2, 2)
    eng.run(_requests(cfg, lens, buds, prefix=32))
    first = eng.stats.prefix_hits
    # same prompts again: EVERY admission now hits the persistent pool
    eng.run(_requests(cfg, lens, buds, prefix=32))
    assert eng.stats.prefix_hits == 2 and eng.stats.prefix_hits >= first


def test_prompt_longer_than_max_len_serves_when_paged(setup):
    cfg, params, _contig, _paged = setup
    kw = dict(batch=2, max_len=48, scheduling="continuous",
              plan_fusion=True, prefill_budget=BUDGET)
    long_req = lambda: _requests(cfg, (150,), (3,))
    with pytest.raises(ValueError, match="exceeds max_seq_len"):
        ServeEngine(cfg, params, **kw).run(long_req())
    # same max_len, but kv_slot_blocks raises the logical capacity to 256
    eng = ServeEngine(cfg, params, **kw, kv_slot_blocks=16, **PG)
    reqs = long_req()
    eng.run(reqs)
    assert len(reqs[0].out_tokens) == 3


# ---------------------------------------------------------------------------
# Degradation: an undersized arena retires instead of crashing or hanging
# ---------------------------------------------------------------------------
def test_tight_pool_completes_gracefully(setup):
    cfg, params, _contig, _paged = setup
    eng = ServeEngine(cfg, params, batch=2, max_len=48,
                      scheduling="continuous", plan_fusion=True,
                      prefill_budget=BUDGET, kv_blocks=8, **PG)
    reqs = _requests(cfg, (41, 41, 41), (3, 3, 3), seed=3)
    eng.run(reqs)                         # must terminate
    served = [r for r in reqs if len(r.out_tokens) == 3]
    starved = {_r for _s, _r, reason in eng.stats.retirements
               if reason == "pool_full"}
    assert len(served) + len(starved) >= 3, \
        (eng.stats.retirements, [len(r.out_tokens) for r in reqs])
