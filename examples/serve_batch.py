"""Batched serving example: spin up the engine on a reduced RecurrentGemma
(hybrid RG-LRU + local attention — O(1) decode state), serve a mixed batch
of requests with greedy and temperature sampling, and verify the greedy
stream against the step-by-step decode oracle.

  PYTHONPATH=src python examples/serve_batch.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("recurrentgemma-2b").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch=4, max_len=96)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new_tokens=12,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(8)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests / {n_tok} tokens in {dt:.2f}s")

    # verify greedy request 0 against the oracle
    r = reqs[0]
    toks = jnp.asarray(np.stack([q.prompt for q in reqs[:4]]))
    cache, logits = lm.prefill(cfg, params, {"tokens": toks}, max_len=96)
    cur = jnp.argmax(logits, -1)
    want = [int(cur[0])]
    for _ in range(11):
        logits, cache = lm.decode_step(cfg, params, cache, cur)
        cur = jnp.argmax(logits, -1)
        want.append(int(cur[0]))
    assert r.out_tokens == want, (r.out_tokens, want)
    print("greedy stream matches the decode oracle:", r.out_tokens)


if __name__ == "__main__":
    main()
