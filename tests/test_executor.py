"""Plan->program executor: interpret-mode ULP-tolerance parity of the
executed train and serve hot paths against the hand-wired references,
dep-forced leftover ops, zero-search replans, binding-contract errors,
schedule-cache LRU ops, and the planner's contracted-cycle guard."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotuner, binding, executor, hfuse, planner
from repro.core.binding import BindingRegistry, Slot
from repro.core.schedule_cache import ScheduleCache
from repro.kernels.adam import adamw_op
from repro.kernels.matmul import matmul_1d_op
from repro.kernels.rmsnorm import rmsnorm_op


# ---------------------------------------------------------------------------
# executor core: ordering, dataflow, leftover ops, error contracts
# ---------------------------------------------------------------------------
def _dep_graph():
    """dW -> adamw (dep-forced leftover: an update can never fuse with the
    matmul producing its own gradient) + an independent fusible partner."""
    dw = dataclasses.replace(
        matmul_1d_op(M=128, K=64, N=128, dtype=jnp.float32, bm=64),
        name="dW_t0", tag="train:dW")
    upd = adamw_op(R=128, dtype=jnp.float32, bm=64, name="adamw_t0")
    nrm = rmsnorm_op(R=256, d=128, dtype=jnp.float32, bm=64)
    return dw, upd, nrm


def _dep_bindings(nrm_name):
    reg = BindingRegistry()
    reg.bind("dW_t0", x="x", w="gy", out="g")
    reg.bind("adamw_t0", scalars="scalars", p="p", g="g", m="m", v="v")
    reg.bind(nrm_name, x="nx", scale="nscale", out="ny")
    return reg


def _dep_state():
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    return {
        "x": jax.random.normal(ks[0], (128, 64)),
        "gy": jax.random.normal(ks[1], (64, 128)) * 0.1,
        "p": jax.random.normal(ks[2], (128, 128)),
        "m": jnp.zeros((128, 128)), "v": jnp.zeros((128, 128)),
        "scalars": (jnp.zeros((1, 128), jnp.float32)
                    .at[0, 0].set(1e-3).at[0, 1].set(0.1).at[0, 2].set(0.05)),
        "nx": jax.random.normal(ks[3], (256, 128)),
        "nscale": jnp.zeros((1, 128), jnp.float32),
    }


def test_executor_dep_forced_leftover_and_dataflow():
    """The graph's dep chain forces dW to stay a single (its only consumer
    depends on it); the fused bundle executes via SearchResult.build();
    live arrays route producer -> consumer through shared state keys."""
    dw, upd, nrm = _dep_graph()
    graph = [planner.GraphOp(dw),
             planner.GraphOp(upd, deps=frozenset({"dW_t0"})),
             planner.GraphOp(nrm)]
    plan = planner.plan(graph, max_ways=3, allow_same_bound=True)
    assert plan.fused, "no bundle admitted"
    assert all("dW_t0" not in d.members for d in plan.fused)

    prog = executor.compile_plan(plan, bindings=_dep_bindings(nrm.name),
                                 interpret=True)
    # the plan covers the graph exactly: every op launches exactly once
    launched = [m for s in prog.steps for m in s.members]
    assert sorted(launched) == sorted(g.op.name for g in graph)
    assert prog.n_fused >= 1
    # dW (single) must run before the bundle containing its consumer
    pos = {m: i for i, s in enumerate(prog.steps) for m in s.members}
    assert pos["dW_t0"] < pos["adamw_t0"]

    state = _dep_state()
    out = jax.jit(prog)(state)
    g_ref = state["x"] @ state["gy"]
    np.testing.assert_allclose(np.asarray(out["g"]), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-5)
    m2, v2 = 0.1 * g_ref, 0.05 * g_ref * g_ref
    p_ref = state["p"] - 1e-3 * ((m2 / 0.1) / (jnp.sqrt(v2 / 0.05) + 1e-8)
                                 + 0.1 * state["p"])
    np.testing.assert_allclose(np.asarray(out["p"]), np.asarray(p_ref),
                               rtol=1e-5, atol=1e-5)


def test_executor_requires_signatures_and_full_bindings():
    from repro.kernels import paper_suite as ps
    op, _, _ = ps.make_maxpool(R=256, C=128, bm=64)      # no signature
    plan = planner.plan([planner.GraphOp(op)])
    with pytest.raises(ValueError, match="no operand signature"):
        executor.compile_plan(plan, bindings=BindingRegistry())

    nrm = rmsnorm_op(R=256, d=128, dtype=jnp.float32, bm=64)
    plan = planner.plan([planner.GraphOp(nrm)])
    reg = BindingRegistry()
    reg.bind(nrm.name, x="nx")                           # scale/out unbound
    with pytest.raises(ValueError, match="unbound operands"):
        executor.compile_plan(plan, bindings=reg)


def test_executor_rejects_plan_graph_mismatch():
    nrm = rmsnorm_op(R=256, d=128, dtype=jnp.float32, bm=64)
    other = dataclasses.replace(nrm, name="other_norm")
    plan = planner.plan([planner.GraphOp(nrm)])
    with pytest.raises(ValueError, match="does not cover"):
        executor.compile_plan(plan, graph=[planner.GraphOp(other)])


def test_executor_default_bindings_roundtrip():
    """default_bindings + synth_state: every named op executes standalone."""
    nrm = rmsnorm_op(R=128, d=128, dtype=jnp.float32, bm=64)
    plan = planner.plan([planner.GraphOp(nrm)])
    prog = executor.compile_plan(
        plan, bindings=binding.default_bindings([nrm]), interpret=True)
    state = binding.synth_state([nrm])
    out = prog(state)
    x = state[f"{nrm.name}.x"].astype(jnp.float32)
    ref = (x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6)
           * (1.0 + state[f"{nrm.name}.scale"]))
    np.testing.assert_allclose(np.asarray(out[f"{nrm.name}.out"]),
                               np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# executed train hot path
# ---------------------------------------------------------------------------
def _cfg():
    from repro.configs import get_config
    return dataclasses.replace(get_config("granite-3-2b").reduced(),
                               dtype="float32")


@pytest.fixture(scope="module")
def train_setup():
    from repro.models import lm
    from repro.train import optimizer as opt_mod
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    return cfg, params, opt_mod.init(params)


def test_executed_update_matches_jnp_and_multi_tensor_adam(train_setup):
    """ULP-tolerance: the planned-and-executed optimizer step == the pure-jnp
    AdamW == the hand-wired multi-tensor Adam kernel, over the full tree."""
    from repro.kernels.adam import multi_tensor_adamw
    from repro.train import optimizer as opt_mod
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_loop import build_update_program

    cfg, params, opt = train_setup
    ocfg = AdamWConfig()
    grads = jax.tree.map(lambda p: p * 0.01 + 0.001, params)
    prog = build_update_program(
        jax.eval_shape(lambda: jax.tree.map(lambda x: x, params)), ocfg)
    assert prog.program.n_fused >= 1, "update program found no bundle"
    # every leaf's update goes through the executor — none hand-wired
    launched = [m for s in prog.program.steps for m in s.members]
    assert len(launched) == len(jax.tree.leaves(params))

    p_ref, s_ref = opt_mod.update(ocfg, grads, opt, params)
    p_exe, s_exe = opt_mod.update(ocfg, grads, opt, params, program=prog)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_exe)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    for tree_ref, tree_exe in ((s_ref.m, s_exe.m), (s_ref.v, s_exe.v)):
        for a, b in zip(jax.tree.leaves(tree_ref), jax.tree.leaves(tree_exe)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    cnt = opt.count + 1
    sc = (jnp.zeros((1, 128), jnp.float32)
          .at[0, 0].set(opt_mod.schedule(ocfg, cnt))
          .at[0, 1].set(1 - ocfg.b1 ** cnt.astype(jnp.float32))
          .at[0, 2].set(1 - ocfg.b2 ** cnt.astype(jnp.float32)))
    mp, _, _ = multi_tensor_adamw(params, grads, opt.m, opt.v, sc,
                                  interpret=True)
    for a, b in zip(jax.tree.leaves(mp), jax.tree.leaves(p_exe)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_executed_train_step_end_to_end(train_setup):
    """A whole jitted train step routed through the executor still learns
    (and matches the hand-wired step bit-for-bit-ish on one step)."""
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_loop import (TrainConfig, build_update_program,
                                        make_train_step)

    cfg, params, opt = train_setup
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=1,
                                             total_steps=10), remat=False)
    prog = build_update_program(
        jax.eval_shape(lambda: jax.tree.map(lambda x: x, params)),
        tcfg.optimizer)
    step_ref = jax.jit(make_train_step(cfg, tcfg))
    step_exe = jax.jit(make_train_step(cfg, tcfg, update_program=prog))
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=4))
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))
    p_ref, o_ref, m_ref = step_ref(params, opt, batch, jnp.asarray(0))
    p_exe, o_exe, m_exe = step_exe(params, opt, batch, jnp.asarray(0))
    assert float(m_ref["loss"]) == pytest.approx(float(m_exe["loss"]), abs=1e-5)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_exe)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_update_program_replan_zero_searches(tmp_path, train_setup):
    """Rebuilding the executed update program for an unchanged tree performs
    ZERO new searches — the SEARCH_COUNT acceptance hook."""
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_loop import build_update_program

    cfg, params, _ = train_setup
    cache = ScheduleCache(tmp_path / "sched.json")
    abstract = jax.eval_shape(lambda: jax.tree.map(lambda x: x, params))
    p1 = build_update_program(abstract, AdamWConfig(), cache=cache)
    n = autotuner.SEARCH_COUNT
    p2 = build_update_program(abstract, AdamWConfig(), cache=cache)
    assert autotuner.SEARCH_COUNT == n, "replan re-searched a bundle"
    assert [s.members for s in p1.program.steps] == \
        [s.members for s in p2.program.steps]


# ---------------------------------------------------------------------------
# executed serve hot path
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def serve_setup():
    from repro.models import lm
    from repro.serve.engine import ServeEngine
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=2, max_len=40, plan_fusion=True,
                      scheduling="wavefront")
    return cfg, params, eng


def test_executed_decode_step_matches_lm_decode(serve_setup):
    """ULP tolerance: the planned norm->attention->FFN program (with the
    model glue in the binding slots) == lm.decode_step."""
    from repro.models import lm
    cfg, params, eng = serve_setup
    assert eng.executed
    toks = jnp.stack([jnp.arange(1, 9, dtype=jnp.int32),
                      jnp.arange(3, 11, dtype=jnp.int32)])
    cache, logits = lm.prefill(cfg, params, {"tokens": toks},
                               max_len=eng.cache_len)
    cur = jnp.argmax(logits, -1)
    for _ in range(3):
        out_ref, cache_ref = lm.decode_step(cfg, params, cache, cur)
        out_exe, cache_exe = eng._decode(params, cache, cur)
        np.testing.assert_allclose(np.asarray(out_exe), np.asarray(out_ref),
                                   rtol=1e-4, atol=2e-5)
        run = [k for k in cache_ref if k != "pos"][0]
        for kk in ("k", "v"):
            np.testing.assert_allclose(np.asarray(cache_exe[run][kk]),
                                       np.asarray(cache_ref[run][kk]),
                                       rtol=1e-5, atol=1e-5)
        cache = cache_exe
        cur = jnp.argmax(out_exe, -1)


def test_executed_engine_tokens_match_handwired(serve_setup):
    """Whole-engine parity across multiple waves (legacy wavefront
    scheduling): the executed decode (and the chunked co-prefill of the
    pending wave, fused with decode attention) produces the same tokens as
    the hand-wired engine.  Continuous-batching parity lives in
    tests/test_serve_continuous.py."""
    from repro.serve.engine import Request, ServeEngine
    cfg, params, eng = serve_setup
    prompts = [np.arange(1, 9, dtype=np.int32),
               np.arange(3, 11, dtype=np.int32),
               np.arange(5, 17, dtype=np.int32),
               np.arange(2, 14, dtype=np.int32)]
    reqs_h = [Request(rid=i, prompt=p, max_new_tokens=4)
              for i, p in enumerate(prompts)]
    reqs_e = [Request(rid=i, prompt=p, max_new_tokens=4)
              for i, p in enumerate(prompts)]
    ServeEngine(cfg, params, batch=2, max_len=40,
                scheduling="wavefront").run(reqs_h)
    eng.run(reqs_e)
    assert [r.out_tokens for r in reqs_e] == [r.out_tokens for r in reqs_h]
    # two prompt lengths -> the mixed (co-prefill) step really compiled
    assert eng._mixed_steps, "co-prefill path never exercised"


def test_serve_mixed_program_fuses_prefill_with_decode_attention(serve_setup):
    """The wavefront mixed program's fused bundle pairs the memory-bound
    cache streaming with the riding prompt's FFN matmul — and no graph op
    is left hand-wired (every member launches via the executor)."""
    _cfg_, _params, eng = serve_setup
    prog = eng.build_decode_program(ffn_rows=128)
    assert prog.n_fused >= 1
    fused_members = [m for s in prog.steps if s.fused for m in s.members]
    assert "prefill_ffn" in fused_members
    assert any(m.startswith("decode_attn") for m in fused_members)
    launched = sorted(m for s in prog.steps for m in s.members)
    assert launched == sorted(g.op.name for g in prog.graph)


def test_decode_program_replan_zero_searches(tmp_path, serve_setup):
    cfg, params, _eng = serve_setup
    from repro.serve.engine import ServeEngine
    cache = ScheduleCache(tmp_path / "sched.json")
    e1 = ServeEngine(cfg, params, batch=2, max_len=40, plan_fusion=True,
                     schedule_cache=cache)
    n = autotuner.SEARCH_COUNT
    e2 = ServeEngine(cfg, params, batch=2, max_len=40, plan_fusion=True,
                     schedule_cache=cache)
    assert autotuner.SEARCH_COUNT == n, "engine restart re-searched"
    assert e1.executed and e2.executed


def test_unsupported_config_falls_back_to_handwired():
    from repro.models import lm
    from repro.serve.engine import (Request, ServeEngine,
                                    executable_decode_supported)
    from repro.configs import get_config
    cfg = dataclasses.replace(get_config("recurrentgemma-2b").reduced(),
                              dtype="float32")
    assert executable_decode_supported(cfg) is not None
    params = lm.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=2, max_len=32, plan_fusion=True)
    assert not eng.executed
    reqs = [Request(rid=0, prompt=np.arange(1, 7, dtype=np.int32),
                    max_new_tokens=2)]
    eng.run(reqs)
    assert len(reqs[0].out_tokens) == 2


# ---------------------------------------------------------------------------
# planner contracted-cycle guard
# ---------------------------------------------------------------------------
def test_planner_never_forms_cyclic_bundles():
    """Two mutually-feeding bundle candidates (att<-n1, pf<-pa) must not
    both form: contracting {att, pa} and {n1, pf} leaves a 2-cycle the
    executor would refuse to toposort.  The planner's acyclicity guard
    keeps the second grouping out, so compile_plan always succeeds."""
    from repro.core.binding import default_bindings, synth_state

    att = dataclasses.replace(
        rmsnorm_op(R=1024, d=512, dtype=jnp.float32, bm=128), name="att")
    pa = dataclasses.replace(
        matmul_1d_op(M=1024, K=512, N=512, dtype=jnp.float32, bm=128),
        name="pa")
    n1 = dataclasses.replace(
        rmsnorm_op(R=896, d=512, dtype=jnp.float32, bm=128), name="n1")
    pf = dataclasses.replace(
        matmul_1d_op(M=896, K=512, N=512, dtype=jnp.float32, bm=128),
        name="pf")
    graph = [planner.GraphOp(n1),
             planner.GraphOp(att, deps=frozenset({"n1"})),
             planner.GraphOp(pa),
             planner.GraphOp(pf, deps=frozenset({"pa"}))]
    plan = planner.plan(graph, max_ways=2, allow_same_bound=True)
    # every accepted grouping stays executable
    ops = [g.op for g in plan.graph]
    prog = executor.compile_plan(plan, bindings=default_bindings(ops),
                                 interpret=True)
    prog(synth_state(ops))
    member_sets = [set(d.members) for d in plan.fused]
    assert not ({"att", "pa"} in member_sets
                and {"n1", "pf"} in member_sets), plan.summary()


# ---------------------------------------------------------------------------
# schedule-cache ops (LRU bound + usage stats + CLI)
# ---------------------------------------------------------------------------
def test_schedule_cache_lru_eviction_and_bound_persists(tmp_path):
    path = tmp_path / "sched.json"
    c = ScheduleCache(path, max_entries=2)
    for i in range(4):
        c.put(f"k{i}", {"ratios": [1], "members": [f"m{i}"]})
    assert len(c.entries) == 2 and c.evictions == 2
    assert set(c.entries) == {"k2", "k3"}
    c.get("k2")                                   # touch -> most recent
    c.put("k9", {"ratios": [2], "members": ["m9"]})
    assert set(c.entries) == {"k2", "k9"}         # LRU victim was k3
    fresh = ScheduleCache(path, max_entries=2)    # bound survives the merge
    assert set(fresh.entries) == {"k2", "k9"}
    st = fresh.stats()
    assert st["entries"] == 2
    assert st["stale_never_reused"] == 1          # k9 never re-consulted


def test_cache_usage_persists_for_pure_hit_replan(tmp_path):
    """A plan() burst of pure cache hits must still persist usage bumps —
    cache-inspect's staleness signal depends on it."""
    nrm = rmsnorm_op(R=128, d=128, dtype=jnp.float32, bm=64)
    mm = matmul_1d_op(M=128, K=128, N=128, dtype=jnp.float32, bm=64)
    graph = [planner.GraphOp(nrm), planner.GraphOp(mm)]
    path = tmp_path / "sched.json"
    planner.plan(graph, allow_same_bound=True, cache=ScheduleCache(path))
    planner.plan(graph, allow_same_bound=True, cache=ScheduleCache(path))
    fresh = ScheduleCache(path)
    assert any(m.get("uses", 0) > 0 for m in fresh.meta.values())


def test_tools_cache_inspect_cli(tmp_path, capsys):
    from repro import tools
    path = tmp_path / "sched.json"
    nrm = rmsnorm_op(R=128, d=128, dtype=jnp.float32, bm=64)
    mm = matmul_1d_op(M=128, K=128, N=128, dtype=jnp.float32, bm=64)
    autotuner.search((nrm, mm), cache=ScheduleCache(path))
    assert tools.main(["cache-inspect", "--cache", str(path), "--json"]) == 0
    import json
    blob = json.loads(capsys.readouterr().out)
    assert blob["stats"]["entries"] == 1
    assert blob["entries"][0]["members"]
    assert tools.main(["cache-inspect", "--cache", str(path)]) == 0
    assert "schedule cache" in capsys.readouterr().out
