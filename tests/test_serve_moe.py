"""MoE on the executed serve path: the router projection and the grouped
expert GMM run as planner ops (serve/engine.decode_graph), with the
top-k/softmax/dispatch/combine glue in binding slots — token-for-token
identical to the hand-wired vmapped fallback, the expert GMM co-resident
in a fused launch, and the three ISSUE-named bugs pinned by regression
tests: the wavefront co-prefill partner width (cfg.d_ff vs the expert
FFN width), the moe_gmm_op capacity/block-divisibility crash, and the
capacity() truncation to 0 at B=1 decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import autotuner
from repro.models import lm
from repro.models import moe as moe_mod
from repro.serve.engine import (PrefillBudget, Request, ServeEngine,
                                executable_decode_supported)


def _cfg(**over):
    cfg = dataclasses.replace(get_config("phi3.5-moe-rms").reduced(),
                              dtype="float32")
    return dataclasses.replace(cfg, **over) if over else cfg


def _requests(cfg, lens, budgets, eos=None, seed=11):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, L).astype(np.int32),
                    max_new_tokens=m, eos_token=eos)
            for i, (L, m) in enumerate(zip(lens, budgets))]


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    budget = PrefillBudget(chunk_rows=8, max_coresident_chunks=2)
    exe = ServeEngine(cfg, params, batch=3, max_len=48,
                      scheduling="continuous", plan_fusion=True,
                      prefill_budget=budget)
    fb = ServeEngine(cfg, params, batch=3, max_len=48,
                     scheduling="continuous", prefill_budget=budget)
    return cfg, params, exe, fb


# ---------------------------------------------------------------------------
# The fence is down: MoE is executor-supported and plans router + GMM
# ---------------------------------------------------------------------------
def test_moe_executable_and_planned(setup):
    cfg, _params, exe, fb = setup
    assert executable_decode_supported(cfg) is None
    assert exe.executed and not fb.executed
    names = [g.op.name for g in exe.decode_graph()]
    assert "moe_router" in names
    assert any(n.startswith("moe_gmm") for n in names)
    # the faithful LayerNorm phi3.5 variant still falls back (norm fence)
    ln = get_config("phi3.5-moe-42b-a6.6b").reduced()
    assert executable_decode_supported(ln) is not None


def test_moe_gmm_co_resident_in_fused_launch(setup):
    cfg, _params, exe, _fb = setup
    prog = exe.build_decode_program(prefill_chunks=2)
    bundles = [ms for ms in prog.fused_members
               if any(m.startswith("moe_gmm") for m in ms)]
    assert bundles and all(len(ms) > 1 for ms in bundles), \
        f"expert GMM not co-resident in any fused launch: {prog.describe()}"


# ---------------------------------------------------------------------------
# Differential parity: executed == vmapped fallback, token for token
# ---------------------------------------------------------------------------
PROMPT_SETS = [
    ((6, 9, 7, 12), (3, 5, 2, 4)),
    ((8, 8, 8, 8, 8), (2, 6, 3, 3, 5)),
    ((10, 5, 20, 6, 9, 7), (4, 4, 1, 6, 2, 3)),   # 20 spans 3 chunks
]


@pytest.mark.parametrize("lens,budgets", PROMPT_SETS)
def test_moe_executed_matches_fallback(setup, lens, budgets):
    cfg, _params, exe, fb = setup
    re_ = _requests(cfg, lens, budgets)
    rf = _requests(cfg, lens, budgets)
    exe.run(re_)
    fb.run(rf)
    assert [r.out_tokens for r in re_] == [r.out_tokens for r in rf]
    st = exe.stats
    assert st.tokens == sum(len(r.out_tokens) for r in re_)
    # expert stats really accumulated, and conserve routed slot-tokens:
    # every decoding slot routes to exactly top_k experts per layer-step
    # (capacity >= B * top_k at this scale, so nothing is ever dropped)
    n_layers = lm.layer_runs(cfg)[0].count
    assert sum(st.expert_hits) == \
        cfg.moe.top_k * st.slot_steps * n_layers


def test_moe_mid_batch_eos(setup):
    cfg, _params, exe, fb = setup
    lens, budgets = (6, 9, 7, 12), (6, 6, 6, 6)
    # probe run picks a token that really appears mid-stream, then both
    # engines must cut that request at the same position
    probe = _requests(cfg, lens, budgets)
    exe.run(probe)
    eos = probe[1].out_tokens[1]
    re_ = _requests(cfg, lens, budgets, eos=eos)
    rf = _requests(cfg, lens, budgets, eos=eos)
    exe.run(re_)
    fb.run(rf)
    assert [r.out_tokens for r in re_] == [r.out_tokens for r in rf]
    assert any(reason == "eos" for _s, _r, reason in exe.stats.retirements)


def test_moe_warm_cache_zero_new_searches(tmp_path):
    from repro.core.schedule_cache import ScheduleCache
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    budget = PrefillBudget(chunk_rows=8, max_coresident_chunks=2)
    sched = ScheduleCache(tmp_path / "sched.json")
    kw = dict(batch=3, max_len=48, scheduling="continuous",
              plan_fusion=True, prefill_budget=budget, schedule_cache=sched)
    ServeEngine(cfg, params, **kw).run(_requests(cfg, (6, 9, 7), (3, 3, 3)))
    n = autotuner.SEARCH_COUNT
    eng = ServeEngine(cfg, params, **kw)
    eng.run(_requests(cfg, (6, 9, 7), (3, 3, 3)))
    assert autotuner.SEARCH_COUNT == n, \
        "warm-cache MoE replan re-searched a bundle"
    assert eng.executed


# ---------------------------------------------------------------------------
# Load-aware admission: eload sheds a coresident chunk under expert skew
# ---------------------------------------------------------------------------
def test_moe_eload_sheds_under_skew():
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    # zero the router: logits all equal, top_k tie-breaks to experts 0 and
    # 1 for EVERY token — skew is exactly E/top_k = 2.0, deterministically
    # above the 1.5 threshold.  Prompt length == chunk == 8 keeps both
    # paths routing identical token groups (no capacity drops), so parity
    # still holds under the pathological router.
    run = lm.layer_runs(cfg)[0]
    blk = dict(params[run.name])
    moe_p = dict(blk["moe"])
    moe_p["router"] = jnp.zeros_like(moe_p["router"])
    blk["moe"] = moe_p
    params = dict(params)
    params[run.name] = blk
    budget = PrefillBudget(chunk_rows=4, max_coresident_chunks=2,
                           policy="eload", skew_threshold=1.5)
    reqs = lambda: _requests(cfg, (8, 8, 8, 8, 8, 8), (4, 4, 4, 4, 4, 4))
    eng = ServeEngine(cfg, params, batch=4, max_len=48,
                      scheduling="continuous", plan_fusion=True,
                      prefill_budget=budget)
    out = reqs()
    eng.run(out)
    st = eng.stats
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    # all hits on experts 0/1, none elsewhere — skew pinned at E/K
    assert st.expert_hits[2:] == [0] * (E - 2)
    assert st.expert_skew == pytest.approx(E / K)
    assert st.load_shed_steps >= 1, \
        "eload never shed a coresident chunk despite 2.0 skew"
    # shedding changes scheduling, never tokens: the fallback agrees
    fb = ServeEngine(cfg, params, batch=4, max_len=48,
                     scheduling="continuous", prefill_budget=budget)
    ref = reqs()
    fb.run(ref)
    assert [r.out_tokens for r in out] == [r.out_tokens for r in ref]


def test_eload_budget_validation():
    assert PrefillBudget(policy="eload").skew_threshold == 1.5
    with pytest.raises(ValueError):
        PrefillBudget(policy="eload", skew_threshold=0.5)
    with pytest.raises(ValueError):
        PrefillBudget(policy="nope")


# ---------------------------------------------------------------------------
# Bugfix 1: wavefront co-prefill partner width is the EXPERT FFN width
# ---------------------------------------------------------------------------
def test_wavefront_partner_width_is_expert_ffn():
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=2, max_len=48,
                      scheduling="continuous")
    graph = eng.decode_graph(ffn_rows=16)
    pf = next(g.op for g in graph if g.op.name == "prefill_ffn")
    m = cfg.moe
    want = 2 * m.d_ff_expert if cfg.activation in ("silu", "gelu") \
        else m.d_ff_expert
    assert pf.inputs[1].shape == (cfg.d_model, want), \
        f"partner is {pf.inputs[1].shape}, not the (gated) expert FFN " \
        f"in-projection (d, {want}) — the cfg.d_ff regression"
    # dense configs keep the dense width
    dcfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                               dtype="float32")
    dparams = lm.init(dcfg, jax.random.PRNGKey(0))
    deng = ServeEngine(dcfg, dparams, batch=2, max_len=48,
                       scheduling="continuous")
    dpf = next(g.op for g in deng.decode_graph(ffn_rows=16)
               if g.op.name == "prefill_ffn")
    dwant = 2 * dcfg.d_ff if dcfg.activation in ("silu", "gelu") \
        else dcfg.d_ff
    assert dpf.inputs[1].shape == (dcfg.d_model, dwant)


# ---------------------------------------------------------------------------
# Bugfix 2: moe_gmm_op clamps bc to a divisor of C (small capacities build)
# ---------------------------------------------------------------------------
def test_moe_gmm_op_small_capacity_builds():
    from repro.kernels.moe_gmm import moe_gmm, moe_gmm_op
    # C=8 against the default bc=128 used to fail `assert C % bc == 0`
    op = moe_gmm_op(E=4, C=8, d=32, f=16, dtype=jnp.float32)
    assert op.inputs[0].block_shape == (1, 8, 32)
    assert op.grid == 4
    # non-power-of-two: bc rounds DOWN to a divisor (12 % 8 != 0 -> 6)
    op = moe_gmm_op(E=2, C=12, d=32, f=16, dtype=jnp.float32, bc=8)
    bc = op.outputs[0].block_shape[1]
    assert 12 % bc == 0 and bc <= 8 and op.grid == 2 * (12 // bc)
    # operand signature is stable for the BindingRegistry
    assert op.in_names == ("xe", "w_in", "w_out")
    assert op.out_names == ("ye",)
    # numerics: the op body matches the reference pallas kernel and the
    # jnp einsum substrate on a small gated case
    rng = np.random.default_rng(0)
    E, C, d, f = 4, 8, 32, 16
    xe = jnp.asarray(rng.standard_normal((E, C, d)), jnp.float32)
    w_in = jnp.asarray(rng.standard_normal((E, d, 2 * f)) * 0.1, jnp.float32)
    w_out = jnp.asarray(rng.standard_normal((E, f, d)) * 0.1, jnp.float32)
    ref = moe_gmm(xe, w_in, w_out, act="silu", interpret=True)
    cfg = _cfg()
    got = moe_mod.expert_ffn(cfg, {"w_in": w_in, "w_out": w_out}, xe)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Bugfix 3: capacity() floors at 1 before block alignment (B=1 decode)
# ---------------------------------------------------------------------------
def test_capacity_floors_at_one_token():
    cfg = _cfg()   # 4 experts top-2, capacity_factor 1.25
    # B=1 decode: int(1 * 2/4 * 1.25) == 0 before the fix
    assert moe_mod.capacity(cfg, 1) >= 1
    assert moe_mod.capacity(cfg, 1) % 8 == 0          # GMM block aligned
    assert moe_mod.capacity(cfg, 1, block=1) == 1     # the raw floor
    # routing a single token must land it (not drop everything)
    r = moe_mod.route_from_logits(
        cfg, jnp.asarray([[0.1, 0.5, 0.2, 0.3]], jnp.float32))
    assert int((r.dispatch_idx == 0).sum()) == cfg.moe.top_k


def test_moe_b1_decode_executed_matches_fallback():
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    budget = PrefillBudget(chunk_rows=8, max_coresident_chunks=1)
    exe = ServeEngine(cfg, params, batch=1, max_len=48,
                      scheduling="continuous", plan_fusion=True,
                      prefill_budget=budget)
    assert exe.executed
    fb = ServeEngine(cfg, params, batch=1, max_len=48,
                     scheduling="continuous", prefill_budget=budget)
    re_ = _requests(cfg, (7, 5), (4, 3))
    rf = _requests(cfg, (7, 5), (4, 3))
    exe.run(re_)
    fb.run(rf)
    assert [r.out_tokens for r in re_] == [r.out_tokens for r in rf]


# ---------------------------------------------------------------------------
# Fences: paths the MoE executed program does not (yet) cover say so
# ---------------------------------------------------------------------------
def test_moe_fenced_paths(setup):
    import types
    cfg, params, _exe, _fb = setup
    # wavefront scheduling serves MoE on the fallback, not the executor
    wf = ServeEngine(cfg, params, batch=2, max_len=48,
                     scheduling="wavefront", plan_fusion=True)
    assert not wf.executed
    # paged KV + MoE is rejected up front (no paged fallback exists)
    with pytest.raises(ValueError, match="MoE"):
        ServeEngine(cfg, params, batch=2, max_len=48,
                    scheduling="continuous", plan_fusion=True,
                    paged_kv=True, kv_block_size=16)
    # tensor-parallel MoE serve is explicitly rejected (expert-major
    # weights are not head/column-sharded)
    fake_mesh = types.SimpleNamespace(shape={"model": 2})
    with pytest.raises(ValueError, match="expert"):
        ServeEngine(cfg, params, batch=2, max_len=48,
                    scheduling="continuous", plan_fusion=True,
                    mesh=fake_mesh, shard_axis="model")
