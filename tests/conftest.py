import os
import sys
from pathlib import Path

# NOTE: do NOT set XLA_FLAGS device-count here — smoke tests and benches must
# see 1 device; only launch/dryrun.py forces 512 (and the sharding tests use
# a subprocess).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
