"""InternVL2-1B — InternViT-300M frontend + Qwen2-0.5B LM backbone
[arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B]

Backbone only per assignment (the ViT frontend is a stub that supplies
precomputed patch embeddings): 24 layers, d_model 896, 14 heads (GQA kv=2),
d_ff 4864, vocab 151655.
"""
from repro.configs.base import ModelConfig, register


@register("internvl2-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab_size=151_655,
        activation="silu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        frontend="vision_stub",
        num_image_tokens=256,
        source="[arXiv:2404.16821; hf] InternViT(stub) + InternLM2/Qwen2 backbone",
    )
