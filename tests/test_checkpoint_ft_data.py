"""Checkpointing (atomicity, integrity, async, elastic reshard), fault
tolerance (watchdog, heartbeats, restart driver), data pipeline
(determinism, shard disjointness, skip-ahead, prefetch)."""
import json
import shutil
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Prefetcher, TokenPipeline
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (HeartbeatMonitor, StepWatchdog,
                                         run_with_restarts)


@pytest.fixture
def tree(rng):
    return {"params": {"w": jax.random.normal(rng, (16, 8)),
                       "b": jnp.ones((8,), jnp.bfloat16)},
            "m": jnp.zeros((16, 8), jnp.float32)}


def test_roundtrip(tmp_path, tree):
    ckpt.save(tmp_path, 7, tree, {"loss": 1.5})
    step, restored, meta = ckpt.restore_latest(tmp_path, tree)
    assert step == 7 and meta["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_corruption_detected_and_skipped(tmp_path, tree):
    ckpt.save(tmp_path, 1, tree)
    ckpt.save(tmp_path, 2, tree)
    # corrupt step 2: truncate a leaf file
    d = tmp_path / "step_0000000002"
    f = next(d.glob("*.bin"))
    f.write_bytes(f.read_bytes()[:10])
    assert ckpt.valid_steps(tmp_path) == [1]
    step, _, _ = ckpt.restore_latest(tmp_path, tree)
    assert step == 1


def test_manifest_digest_tamper(tmp_path, tree):
    ckpt.save(tmp_path, 3, tree)
    mf = tmp_path / "step_0000000003" / "manifest.json"
    m = json.loads(mf.read_text())
    m["metadata"]["loss"] = 999
    mf.write_text(json.dumps(m))
    assert ckpt.valid_steps(tmp_path) == []


def test_async_checkpointer_and_gc(tmp_path, tree):
    ac = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in [1, 2, 3, 4]:
        ac.save_async(s, tree)
    ac.wait()
    assert ckpt.valid_steps(tmp_path) == [3, 4]


def test_elastic_reshard_roundtrip(tmp_path, tree):
    """Restore with explicit (different) shardings — single-device here, but
    exercises the device_put path used for mesh-A -> mesh-B rescale."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    ckpt.save(tmp_path, 5, tree)
    _, restored, _ = ckpt.restore_latest(tmp_path, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


# ---------------------------------------------------------------------------
def test_watchdog_flags_planted_straggler():
    wd = StepWatchdog(k=3.0)
    flagged = [wd.observe(i, 0.1 + 0.001 * (i % 3)) for i in range(20)]
    assert not any(flagged)
    assert wd.observe(20, 1.5)                    # 15x slower: straggler
    assert wd.stragglers and wd.stragglers[0][0] == 20
    # healthy stats not poisoned: next normal step is not flagged
    assert not wd.observe(21, 0.1)


def test_heartbeat_dead_host_and_rescale():
    t = [0.0]
    mon = HeartbeatMonitor(["h0", "h1", "h2", "h3"], deadline_s=10,
                           clock=lambda: t[0])
    t[0] = 5.0
    for h in ["h0", "h1", "h2"]:
        mon.beat(h)
    t[0] = 12.0
    assert mon.dead_hosts() == ["h3"]
    assert mon.plan_rescale((4, 1)) == (3, 1)


def test_run_with_restarts_resumes(tmp_path):
    calls = {"n": 0}

    def make_state():
        return {"fail_at": 3}

    def loop(state, failures):
        calls["n"] += 1
        if failures == 0:
            raise RuntimeError("injected node failure")
        return "done"

    assert run_with_restarts(make_state, loop, max_failures=2) == "done"
    assert calls["n"] == 2


def test_run_with_restarts_bounded():
    def loop(state, failures):
        raise RuntimeError("always fails")
    with pytest.raises(RuntimeError):
        run_with_restarts(dict, loop, max_failures=2)


# ---------------------------------------------------------------------------
def test_data_determinism_and_restart():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    p1 = TokenPipeline(cfg)
    b_at_5 = p1.batch_at(5)
    p2 = TokenPipeline(cfg)
    p2.restore({"step": 5, "shard": 0})
    b2 = next(p2)
    np.testing.assert_array_equal(b_at_5["tokens"], b2["tokens"])
    assert (b_at_5["labels"][:, :-1] == b_at_5["tokens"][:, 1:]).all()


def test_data_shards_differ_and_split_batch():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    s0 = TokenPipeline(cfg, shard=0, num_shards=4)
    s1 = TokenPipeline(cfg, shard=1, num_shards=4)
    assert s0.local_batch == 2
    assert not np.array_equal(s0.batch_at(0)["tokens"],
                              s1.batch_at(0)["tokens"])


def test_skip_ahead_and_prefetch():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    p = TokenPipeline(cfg)
    p.skip_ahead(3)
    want = p.batch_at(3)
    got = next(p)
    np.testing.assert_array_equal(want["tokens"], got["tokens"])
    pf = Prefetcher(TokenPipeline(cfg), depth=2)
    b0, b1 = next(pf), next(pf)
    assert b0["tokens"].shape == (2, 8)
    pf.close()


def test_vlm_batch_masks_image_positions():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2,
                     num_image_tokens=4, d_model=8)
    b = TokenPipeline(cfg).batch_at(0)
    assert b["pixel_embeds"].shape == (2, 4, 8)
    assert (b["labels"][:, :4] == -1).all()
