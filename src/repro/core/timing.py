"""Measurement harness — the profiler inside the paper's Main() loop (Fig. 6).

The paper picks schedules by *running* each candidate and keeping the
fastest; our autotuner accepts that as ``search(..., measure=)`` but until
now nothing ever provided the callable.  ``make_measure(backend=...)``
builds it:

  tpu / gpu   — wall clock: synthesize operands from the OpSpecs, one
                compile+warmup pass, then ``repeats`` timed runs with
                ``jax.block_until_ready`` and a trimmed mean (drop the
                ``trim`` fastest/slowest — interrupt noise).
  interpret   — deterministic step-count proxy so CI exercises the
                *identical* measured-search code path on CPU: the score is
                the fused grid length x the bundle's mean per-step roofline
                work.  Schedules that waste fused steps (phase windows past
                a member's grid) genuinely score worse, so the proxy ranks
                schedules, it doesn't just rubber-stamp the cost model.
                ``execute=True`` additionally runs each candidate kernel in
                interpret mode on tiny synthesized inputs (numerics-path
                exercise; only sane for reduced-size ops).

The returned callable has the ``measure(fused, *ops) -> seconds`` contract
``autotuner.search`` expects, where ``fused`` is a ``hfuse.generate`` (or
``run_native``) callable and ``ops`` are the bundle members.
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.cost_model import LAUNCH_S
from repro.core.op_spec import OpSpec


def resolve_backend(backend: str = "auto") -> str:
    """'auto' -> the JAX default backend, with CPU mapped to 'interpret'."""
    if backend != "auto":
        return backend
    be = jax.default_backend()
    return be if be in ("tpu", "gpu") else "interpret"


def synth_inputs(ops: Sequence[OpSpec], seed: int = 0) -> list[jax.Array]:
    """Synthesize one flat operand list for a bundle from its OpSpecs.

    Floats get small-magnitude normals (saturating bodies like tanh rounds
    stay in-range); everything else gets zeros.  Timing only — numerics are
    the tests' job.
    """
    key = jax.random.PRNGKey(seed)
    arrs: list[jax.Array] = []
    for op in ops:
        for o in op.inputs:
            key, sub = jax.random.split(key)
            if jnp.issubdtype(jnp.dtype(o.dtype), jnp.floating):
                arrs.append(jax.random.normal(sub, o.shape).astype(o.dtype)
                            * 0.1)
            else:
                arrs.append(jnp.zeros(o.shape, o.dtype))
    return arrs


def step_time_proxy(fused, ops: Sequence[OpSpec]) -> float:
    """Deterministic interpret-mode score: fused-grid length x mean step work.

    ``fused.n_steps`` (set by hfuse.generate) is the realized fused grid:
    ``period * max_i ceil(grid_i / r_i)``.  A schedule that keeps every
    member busy end-to-end has n_steps ~= sum(grid_i); imbalanced ratios
    leave idle phase slots and n_steps grows — the proxy charges for them.
    Callables without ``n_steps`` (e.g. ``run_native``) are charged the
    exact per-op work plus one launch per op.
    """
    total_work = sum(op.t_compute + op.t_memory for op in ops)
    total_steps = sum(op.grid for op in ops)
    n_steps = getattr(fused, "n_steps", None)
    if n_steps is None:                     # native baseline: N launches
        return total_work + len(ops) * LAUNCH_S
    return n_steps * (total_work / max(total_steps, 1)) + LAUNCH_S


def make_measure(backend: str = "auto", *, warmup: int = 2, repeats: int = 5,
                 trim: int = 1, execute: bool = False,
                 seed: int = 0) -> Callable:
    """Build the ``measure(fused, *ops) -> seconds`` callable for
    ``autotuner.search(measure=)`` / ``planner.plan(measure=)``."""
    backend = resolve_backend(backend)

    if backend == "interpret":
        def measure(fused, *ops):
            if execute and hasattr(fused, "schedule"):
                from repro.core import hfuse
                interp = hfuse.generate(ops, fused.schedule, interpret=True)
                jax.block_until_ready(interp(*synth_inputs(ops, seed)))
            return step_time_proxy(fused, ops)
        measure.backend = "interpret"
        # the proxy RANKS schedules; its native-vs-fused difference is only
        # launch amortization, so absolute gains are meaningless — consumers
        # (planner admission) must fall back to predicted gain
        measure.rank_only = True
        return measure

    def measure(fused, *ops):
        args = synth_inputs(ops, seed)
        for _ in range(max(1, warmup)):       # compile + cache warm
            jax.block_until_ready(fused(*args))
        ts = []
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            jax.block_until_ready(fused(*args))
            ts.append(time.perf_counter() - t0)
        ts.sort()
        k = trim if len(ts) > 2 * trim else 0
        kept = ts[k:len(ts) - k] if k else ts
        return sum(kept) / len(kept)

    measure.backend = backend
    return measure
