"""Dual-stream serving demo — the paper's scenario inside a transformer.

A decode wave's attention (memory-bound: streams the 32k KV cache, ~2 flops
per byte) and a chunked-prefill FFN matmul (compute-bound, AI ~ 1000) are
horizontally fused by the autotuner-chosen schedule; the Pallas pipeline
overlaps the cache DMA stream with the MXU matmul — the paper's
Ethash+Blake256 case realized in a serving step.

  PYTHONPATH=src python examples/dual_stream_decode.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotuner, hfuse
from repro.core.cost_model import native_time
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_op
from repro.kernels.matmul import matmul_1d_op


def main():
    # --- production-scale specs (cost model; TPU v5e target) --------------
    att_big = decode_attention_op(B=16, S=32768, H=8, Hkv=2, D=64,
                                  dtype=jnp.bfloat16, ck=2048)
    mm_big = matmul_1d_op(2048, 2048, 8192, dtype=jnp.bfloat16, bm=128)
    res = autotuner.search((att_big, mm_big))
    print(f"decode-attn:  {att_big.bound}-bound, "
          f"AI={att_big.arithmetic_intensity:.1f}, "
          f"t_native={native_time(att_big) * 1e6:.0f}us")
    print(f"prefill-FFN:  {mm_big.bound}-bound, "
          f"AI={mm_big.arithmetic_intensity:.1f}, "
          f"t_native={native_time(mm_big) * 1e6:.0f}us")
    print(f"best schedule {res.best.sched.ra}:{res.best.sched.rb}  "
          f"predicted speedup {res.best.est.speedup_pct():.1f}%")
    print("search log (paper Fig. 6 Main()):")
    for row in res.table()[:8]:
        print("  ", row)

    # --- numerics at reduced size (interpret mode on CPU) ------------------
    att = decode_attention_op(B=2, S=512, H=8, Hkv=2, D=64,
                              dtype=jnp.float32, ck=128)
    mm = matmul_1d_op(256, 128, 256, dtype=jnp.float32, bm=64)
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (2, 8, 64), jnp.float32)
    kc = jax.random.normal(ks[1], (2, 512, 2, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (2, 512, 2, 64), jnp.float32)
    x = jax.random.normal(ks[3], (256, 128), jnp.float32)
    w = jax.random.normal(ks[4], (128, 256), jnp.float32) * 0.1
    fused = hfuse.generate(att, mm, res.best.sched, interpret=True)
    o_att, _m, _l, o_mm = fused(q, kc, vc, x, w)
    err1 = float(np.max(np.abs(np.asarray(o_att)
                               - np.asarray(ref.decode_attention(q, kc, vc, 512)))))
    err2 = float(np.max(np.abs(np.asarray(o_mm) - np.asarray(ref.matmul(x, w)))))
    print(f"fused == separate: attention err {err1:.2e}, matmul err {err2:.2e}")


if __name__ == "__main__":
    main()
