"""Quickstart: automatic horizontal fusion in 40 lines.

Describe two kernels with complementary resource profiles, let the planner
pair them, the autotuner pick the thread-space partition (interleave
schedule), and Generate() emit the fused Pallas kernel — then check it
against the oracles.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.core import planner
from repro.kernels import paper_suite as ps


def main():
    # a memory-bound kernel (streams a 32MB DAG) ...
    ethash, mk_eth, ref_eth = ps.make_ethash_like(R_dag=16384, bm=512)
    # ... and a compute-bound one (24 rounds of mixing matmuls)
    blake, mk_blk, ref_blk = ps.make_blake_like(R=4096, bm=512)
    print("ethash profile:", ethash.describe())
    print("blake  profile:", blake.describe())

    plan = planner.plan([planner.GraphOp(ethash), planner.GraphOp(blake)])
    for row in plan.summary():
        print(row)

    decision = plan.fused[0]
    fused = decision.result.build(interpret=True)   # interpret: CPU container

    xa = mk_eth(jax.random.PRNGKey(0))
    xb = mk_blk(jax.random.PRNGKey(1))
    outs = fused(*xa, *xb)
    err_a = float(np.max(np.abs(np.asarray(outs[0]) - np.asarray(ref_eth(*xa)))))
    err_b = float(np.max(np.abs(np.asarray(outs[1], np.float32)
                                - np.asarray(ref_blk(*xb), np.float32))))
    print(f"fused kernel == native kernels: max err {max(err_a, err_b):.2e}")
    print(f"predicted speedup on TPU v5e: "
          f"{decision.predicted_speedup_pct:.1f}% "
          f"(schedule {decision.result.best.sched.ra}:"
          f"{decision.result.best.sched.rb})")


if __name__ == "__main__":
    main()
