"""jit'd public wrappers for every kernel: Pallas on TPU, interpret-Pallas or
the jnp oracle elsewhere (this container is CPU-only; TPU is the target).

`use_pallas()` decides per-platform; `force` overrides for tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import adam as adam_k
from repro.kernels import decode_attention as dec_k
from repro.kernels import flash_attention as fa_k
from repro.kernels import matmul as mm_k
from repro.kernels import moe_gmm as gmm_k
from repro.kernels import ref
from repro.kernels import rmsnorm as rn_k

_FORCE: Optional[str] = None      # None | "pallas" | "interpret" | "ref"


def force(mode: Optional[str]):
    global _FORCE
    _FORCE = mode


def _mode() -> str:
    if _FORCE:
        return _FORCE
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, w, bm=512, bn=512, bk=512):
    m = _mode()
    if m == "ref":
        return ref.matmul(x, w)
    return mm_k.matmul(x, w, bm=bm, bn=bn, bk=bk, interpret=(m == "interpret"))


@jax.jit
def rmsnorm(x, scale):
    m = _mode()
    if m == "ref":
        return ref.rmsnorm(x, scale)
    return rn_k.rmsnorm(x, scale, interpret=(m == "interpret"))


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q, k, v, causal=True):
    """q,k,v: (B,S,H,D) — GQA handled by repeating KV heads to H."""
    m = _mode()
    if m == "ref":
        B, S, H, D = q.shape
        rep = H // k.shape[2]
        kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k
        vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v
        return ref.flash_attention(q, kr, vr, causal=causal)
    B, S, H, D = q.shape
    rep = H // k.shape[2]
    kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kt = kr.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vt = vr.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    o = fa_k.flash_attention(qt, kt, vt, causal=causal,
                             interpret=(m == "interpret"))
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("act", "bc"))
def moe_gmm(xe, w_in, w_out, act="silu", bc=128):
    m = _mode()
    if m == "ref":
        return ref.moe_gmm(xe, w_in, w_out, act=act)
    return gmm_k.moe_gmm(xe, w_in, w_out, act=act, bc=bc,
                         interpret=(m == "interpret"))


def hfused_adamw(params, grads, m, v, *, lr, b1, b2, eps, wd, bc1, bc2):
    """All per-tensor updates as ONE Pallas launch (paper §4.3 form).

    Pallas/interpret modes run the N-way multi-tensor bundle (one OpSpec
    per tensor, horizontally fused by core/hfuse); ref mode applies the
    oracle update leaf-wise.
    """
    mode = _mode()
    if mode == "ref":
        lp, treedef = jax.tree.flatten(params)
        outs = [ref.adamw(p, g, mm.astype(jnp.float32),
                          vv.astype(jnp.float32), lr=lr, b1=b1, b2=b2,
                          eps=eps, wd=wd, bc1=bc1, bc2=bc2)
                for p, g, mm, vv in zip(lp, treedef.flatten_up_to(grads),
                                        treedef.flatten_up_to(m),
                                        treedef.flatten_up_to(v))]
        return tuple(jax.tree.unflatten(treedef, [o[k] for o in outs])
                     for k in range(3))
    scal = jnp.zeros((1, adam_k.LANES), jnp.float32)
    scal = scal.at[0, 0].set(lr).at[0, 1].set(bc1).at[0, 2].set(bc2)
    return adam_k.multi_tensor_adamw(params, grads, m, v, scal,
                                     b1=b1, b2=b2, eps=eps, wd=wd,
                                     interpret=(mode == "interpret"))
