"""Three-term roofline cost model for fusion decisions (TPU v5e).

This is the napkin-math engine behind the planner and the autotuner — the
role profiling plays in the paper's ``Main()`` (Fig. 6).  The fundamental
inequality of horizontal fusion, generalized to an N-op bundle:

    t_native(K1;..;KN) = Σ_i max(tc_i, tm_i)           (N kernels, serial)
    t_hfused(K1∪..∪KN) ≈ max(Σ_i tc_i, Σ_i tm_i)       (engines overlap)

    gain = t_native − t_hfused ≥ 0, strictly > 0  iff  the bundle mixes
    bound kinds (memory- and compute-bound members) — the paper's §IV-C
    finding (Ethash+Blake256 wins, Blake256+SHA256 loses) falls out
    directly, and extends: a second memory-bound op joining a
    compute-dominated bundle still rides the idle HBM engine for free.

VMEM pressure is the occupancy analogue: the fused kernel needs every
member's blocks resident (×2 for double buffering).  Exceeding the budget
forfeits pipelining — modeled as degrading overlap from max(Σc, Σm) toward
Σc+Σm — the same cliff the paper's register-cap search navigates.
"""
from __future__ import annotations

import json
import math
import os
import re
from dataclasses import dataclass
from typing import Optional, Sequence

from repro.core.op_spec import OpSpec
from repro.distributed.hlo_analysis import HBM_BW, PEAK_FLOPS, VMEM_BYTES

VMEM_BUDGET = int(VMEM_BYTES * 0.8)        # leave headroom for spills/semaphores

# Sub-roofline terms (TPU v5e).  The paper's GPU gains come partly from
# effects *below* the roofline (issue-slot stalls); the TPU analogues we
# model are (a) kernel launch/teardown (~2us — paper footnote 1: fusion
# amortizes it N-fold) and (b) the pipeline ramp: the first block's DMA and
# the last block's compute have nothing to overlap with (one (tc+tm)/N per
# kernel; the fused kernel pays it once).  Same-resource bundles gain only
# these small terms on TPU (and can lose via VMEM pressure) — the honest
# adaptation finding, recorded in EXPERIMENTS.md §Paper-validation.
LAUNCH_S = 2e-6

# Interleave-ratio domain shared by the candidate lattice and the
# autotuner's coordinate descent — one bound, one search space.
MAX_RATIO = 4096

# ---------------------------------------------------------------------------
# Measured-delta corrections (fitted, default OFF)
#
# The measured-mode search records cm_vs_measured_delta_pct per bundle;
# ``python -m repro.tools fit-cost`` distills the accumulated history
# (benchmarks/history/BENCH_measured_*.json) into a per-op-class
# multiplicative correction table — clamped medians of measured/predicted.
# The table is consulted only when loaded ($REPRO_COST_CORRECTIONS=<path>
# or set_corrections(...)); with nothing loaded every factor is exactly
# 1.0 and the model is byte-for-byte the analytic roofline above.
# ---------------------------------------------------------------------------
CORRECTION_CLAMP = (0.5, 2.0)

# parameter segments in generated op names (B3, S128, H4kv4, C8, pg16, 1d):
# a short alpha prefix followed by a digit, or a leading digit
_PARAM_SEG = re.compile(r"^[A-Za-z]{0,3}\d")
_CHAIN_SEP = "→"                       # stitch.CHAIN_SEP, sans import

_corrections: Optional[dict] = None
_corrections_env_loaded = False


def op_class(name: str) -> str:
    """Stable class key for an op name: shape/index parameters stripped.
    ``decode_attn_B3_S128_H4kv4`` and ``decode_attn_B2_S256_H8kv4`` are one
    class; ``prefill_attn0_C8_...`` and ``prefill_attn1_C16_...`` are one
    class; a stitched chain is the chain of its members' classes."""
    if _CHAIN_SEP in name:
        return _CHAIN_SEP.join(op_class(p) for p in name.split(_CHAIN_SEP))
    kept = []
    for seg in name.split("_"):
        if _PARAM_SEG.match(seg):
            continue                        # B3 / S128 / H4kv4 / 1d / pg16
        kept.append(seg.rstrip("0123456789"))   # norm1 -> norm, attn0 -> attn
    return "_".join(s for s in kept if s) or name


def set_corrections(table: Optional[dict]) -> None:
    """Install (or clear, with None) the per-op-class correction table:
    ``{class: factor}`` or the fit-cost file schema ``{"classes": {class:
    {"correction": factor, ...}}}``."""
    global _corrections, _corrections_env_loaded
    if table is not None and "classes" in table:
        table = {k: float(v["correction"] if isinstance(v, dict) else v)
                 for k, v in table["classes"].items()}
    _corrections = table
    _corrections_env_loaded = True          # explicit call wins over env


def _correction_table() -> Optional[dict]:
    global _corrections_env_loaded
    if not _corrections_env_loaded:
        _corrections_env_loaded = True
        path = os.environ.get("REPRO_COST_CORRECTIONS")
        if path:
            try:
                with open(path) as fh:
                    set_corrections(json.load(fh))
            except (OSError, json.JSONDecodeError, KeyError, TypeError,
                    ValueError):
                pass                        # unreadable table == no table
    return _corrections


def correction_for(name: str) -> float:
    """Fitted multiplicative factor for this op's class (1.0 unless a
    table is loaded and carries the class)."""
    table = _correction_table()
    if not table:
        return 1.0
    lo, hi = CORRECTION_CLAMP
    return min(hi, max(lo, float(table.get(op_class(name), 1.0))))


def native_time(op: OpSpec) -> float:
    """Standalone kernel wall-time model: roofline + ramp + launch."""
    ramp = (op.t_compute + op.t_memory) / max(op.grid, 1)
    return (max(op.t_compute, op.t_memory) + ramp) * correction_for(op.name) \
        + LAUNCH_S


class Schedule:
    """Interleave ratio vector: r_i steps of op i per super-step, in order.

    ``Schedule(ratios)`` takes the N-way ratio tuple; ``Schedule(ra, rb)``
    is the 2-op form (the paper's thread-partition point d1): it sets how
    much of each op is in flight per super-step.  DMA-elision index maps
    (core/hfuse.py) hold each op's blocks outside its own phase.
    """
    __slots__ = ("ratios",)

    def __init__(self, *args):
        if len(args) == 1 and not isinstance(args[0], int):
            ratios = tuple(int(r) for r in args[0])
        else:
            ratios = tuple(int(a) for a in args)
        if not ratios or any(r < 1 for r in ratios):
            raise ValueError(f"ratios must be positive ints, got {ratios}")
        object.__setattr__(self, "ratios", ratios)

    @property
    def n_ops(self) -> int:
        return len(self.ratios)

    @property
    def ra(self) -> int:
        return self.ratios[0]

    @property
    def rb(self) -> int:
        return self.ratios[1]

    @property
    def period(self) -> int:
        return sum(self.ratios)

    def offsets(self) -> tuple[int, ...]:
        """Phase start of each op within the super-step."""
        offs, acc = [], 0
        for r in self.ratios:
            offs.append(acc)
            acc += r
        return tuple(offs)

    def label(self) -> str:
        return ":".join(str(r) for r in self.ratios)

    def __eq__(self, other):
        return isinstance(other, Schedule) and self.ratios == other.ratios

    def __hash__(self):
        return hash(self.ratios)

    def __repr__(self):
        return f"Schedule({self.ratios})"


@dataclass
class FusedEstimate:
    t_native: float
    t_vfused: float
    t_hfused: float
    gain_vs_native: float
    gain_vs_vfused: float
    vmem_bytes: int
    vmem_ok: bool
    overlap_eff: float

    def speedup_pct(self) -> float:
        return 100.0 * self.gain_vs_native / max(self.t_native, 1e-30)


def _as_bundle(args) -> tuple[tuple[OpSpec, ...], Schedule]:
    """Accept (a, b, sched) legacy positionals or (ops, sched)."""
    if isinstance(args[0], OpSpec):
        *ops, sched = args
        ops = tuple(ops)
    else:
        ops, sched = tuple(args[0]), args[1]
    if sched.n_ops != len(ops):
        raise ValueError(
            f"schedule has {sched.n_ops} ratios for {len(ops)} ops")
    return ops, sched


def hfused_cost(*args, vmem_budget: int = VMEM_BUDGET) -> FusedEstimate:
    """Cost of the interleaved fused bundle under a schedule.

    ``hfused_cost(ops, sched)`` for an N-op bundle, or the legacy 2-op
    ``hfused_cost(a, b, sched)``.
    """
    ops, sched = _as_bundle(args)
    corr = [correction_for(op.name) for op in ops]
    tcs = [op.t_compute * c for op, c in zip(ops, corr)]
    tms = [op.t_memory * c for op, c in zip(ops, corr)]
    ramps = [(tc + tm) / max(op.grid, 1)
             for op, tc, tm in zip(ops, tcs, tms)]
    t_native = sum(native_time(op) for op in ops)       # N launches
    # vertical/concatenated baseline: one kernel, phases stay serial;
    # saves N-1 launches + all but one boundary ramp (paper footnote 1)
    t_vfused = sum(max(tc, tm) for tc, tm in zip(tcs, tms)) \
        + max(ramps) + LAUNCH_S

    # The interleave ratios control how long the ops co-execute: with grids
    # N_i and ratios r_i, full co-execution lasts until the shortest op (in
    # super-steps) is exhausted; each op's leftover runs progressively less
    # overlapped — modeled as its un-overlapped tail.
    ss = [math.ceil(op.grid / r) for op, r in zip(ops, sched.ratios)]
    co = min(ss)                            # super-steps with all ops active
    fs = [co / s for s in ss]
    # overlapped portion: engines add across the bundle; tails: leftovers
    t_overlap = max(sum(f * tc for f, tc in zip(fs, tcs)),
                    sum(f * tm for f, tm in zip(fs, tms)))
    t_tail = sum(max((1 - f) * tc, (1 - f) * tm)
                 for f, tc, tm in zip(fs, tcs, tms))

    # VMEM: every member's blocks resident, double-buffered
    vmem = 2 * sum(op.vmem_bytes for op in ops)
    vmem_ok = vmem <= vmem_budget
    ramp_fused = max(ramps)
    if vmem_ok:
        t_h = t_overlap + t_tail + ramp_fused + LAUNCH_S
        eff = 1.0
    else:
        # pipelining forfeited: DMA and compute serialize (the "occupancy
        # cliff'); interpolate by how far over budget we are
        over = min(2.0, vmem / vmem_budget)
        serial = sum(f * tc for f, tc in zip(fs, tcs)) \
            + sum(f * tm for f, tm in zip(fs, tms))
        t_h = t_tail + t_overlap + (serial - t_overlap) * (over - 1.0) \
            + ramp_fused + LAUNCH_S
        eff = max(0.0, 2.0 - over)
    return FusedEstimate(
        t_native=t_native, t_vfused=t_vfused, t_hfused=t_h,
        gain_vs_native=t_native - t_h, gain_vs_vfused=t_vfused - t_h,
        vmem_bytes=vmem, vmem_ok=vmem_ok, overlap_eff=eff)


def fusion_profitable(a: OpSpec, b: OpSpec) -> bool:
    """The paper's scenario test: different bound kinds => profitable."""
    return a.bound != b.bound


def bundle_profitable(ops: Sequence[OpSpec]) -> bool:
    """N-way scenario test: the bundle must mix bound kinds — an all-
    compute (or all-memory) bundle only saves launches (Blake256+SHA256)."""
    return len({op.bound for op in ops}) > 1


def ratio_candidates(*args, max_ratio: int = MAX_RATIO) -> list[Schedule]:
    """Candidate interleave ratio vectors ~ the paper's d1 sweep.

    ``ratio_candidates(ops)`` for a bundle or legacy ``ratio_candidates(a, b)``.
    Includes the grid-proportional vector (so wildly imbalanced grids —
    e.g. a 2048-step decode-attention stream vs a 4-step prefill matmul —
    co-execute end-to-end) plus scaled neighbours and per-op boosts."""
    if isinstance(args[0], OpSpec):
        ops = tuple(args)
    else:
        ops = tuple(args[0])
    n = len(ops)
    cands = {(1,) * n}
    # boost one op at a time (generalizes (2,1),(1,2),(4,1),(1,4))
    for i in range(n):
        for r in (2, 4):
            v = [1] * n
            v[i] = r
            cands.add(tuple(v))
    # grid-proportional vector and its half/double neighbours
    gmin = max(1, min(op.grid for op in ops))
    for s in (0.5, 1.0, 2.0):
        cands.add(tuple(
            max(1, min(max_ratio, round(op.grid * s / gmin))) for op in ops))
    return [Schedule(v) for v in sorted(cands)]
