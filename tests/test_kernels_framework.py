"""Framework kernels (matmul, rmsnorm, flash/decode attention, MoE GMM,
fused Adam) vs oracles, sweeping shapes/dtypes in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hfuse
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_op
from repro.kernels.matmul import matmul_1d_op
from repro.kernels.rmsnorm import rmsnorm_op


@pytest.fixture(autouse=True)
def interpret_mode():
    ops.force("interpret")
    yield
    ops.force(None)


@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (256, 128, 128, 128, 128, 128),
    (512, 256, 384, 256, 128, 128),
    (128, 512, 256, 128, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul(M, K, N, bm, bn, bk, dtype, rng):
    x = jax.random.normal(rng, (M, K), dtype)
    w = jax.random.normal(jax.random.PRNGKey(7), (K, N), dtype)
    got = ops.matmul(x, w, bm=bm, bn=bn, bk=bk)
    want = ref.matmul(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("R,d", [(256, 128), (512, 512), (128, 384)])
def test_rmsnorm(R, d, rng):
    x = jax.random.normal(rng, (R, d), jnp.float32)
    s = jax.random.normal(jax.random.PRNGKey(3), (d,), jnp.float32) * 0.1
    np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, s)),
                               np.asarray(ref.rmsnorm(x, s)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,H,Hkv,D", [(128, 4, 4, 64), (256, 4, 2, 64),
                                       (256, 8, 1, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(S, H, Hkv, D, causal, rng):
    B = 2
    q = jax.random.normal(rng, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal)
    ops.force("ref")
    want = ops.flash_attention(q, k, v, causal=causal)
    ops.force("interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


@pytest.mark.parametrize("B,S,H,Hkv,D,ck", [(2, 512, 8, 2, 64, 128),
                                            (1, 256, 4, 4, 128, 256),
                                            (4, 1024, 8, 1, 64, 512)])
def test_decode_attention_op(B, S, H, Hkv, D, ck, rng):
    op = decode_attention_op(B=B, S=S, H=H, Hkv=Hkv, D=D,
                             dtype=jnp.float32, ck=ck)
    q = jax.random.normal(rng, (B, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(5), (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(6), (B, S, Hkv, D), jnp.float32)
    outs = hfuse.run_single(op, interpret=True)(q, k, v)
    want = ref.decode_attention(q, k, v, S)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(want),
                               atol=3e-5)


def test_decode_attention_per_slot_lengths(rng):
    """dynamic_length: the (B, 1) int32 operand masks each slot at its OWN
    valid prefix — row b of the vectorized kernel equals a scalar-length
    reference run at length[b] (the continuous-batching cache contract;
    the hypothesis sweep lives in test_decode_attention_vec.py)."""
    B, S, H, Hkv, D, ck = 3, 256, 4, 2, 32, 64
    op = decode_attention_op(B=B, S=S, H=H, Hkv=Hkv, D=D,
                             dtype=jnp.float32, ck=ck, dynamic_length=True)
    q = jax.random.normal(rng, (B, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(5), (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(6), (B, S, Hkv, D), jnp.float32)
    lens = jnp.asarray([[1], [100], [256]], jnp.int32)
    o, _m, _l = hfuse.run_single(op, interpret=True)(lens, q, k, v)
    for b, L in enumerate([1, 100, 256]):
        want = ref.decode_attention(q[b:b + 1], k[b:b + 1, :L],
                                    v[b:b + 1, :L], L)
        np.testing.assert_allclose(np.asarray(o[b]), np.asarray(want)[0],
                                   atol=3e-5)


@pytest.mark.parametrize("E,C,d,f,act", [(4, 256, 64, 32, "silu"),
                                         (8, 128, 128, 64, "gelu")])
def test_moe_gmm(E, C, d, f, act, rng):
    xe = jax.random.normal(rng, (E, C, d), jnp.float32)
    win = jax.random.normal(jax.random.PRNGKey(1), (E, d, 2 * f)) * 0.1
    wout = jax.random.normal(jax.random.PRNGKey(2), (E, f, d)) * 0.1
    got = ops.moe_gmm(xe, win, wout, act=act)
    want = ref.moe_gmm(xe, win, wout, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_hfused_adam_matches_per_tensor(rng):
    """One N-way fused multi-tensor launch == N per-tensor reference updates.

    Tolerance is a few f32 ULPs, not bitwise: the kernel takes lr/bc1/bc2 as
    *runtime* scalars (an LR schedule must not trigger a recompile every
    step), while the oracle bakes them as Python constants — XLA strength-
    reduces division-by-constant to reciprocal multiplies, a 1-2 ULP rewrite
    the runtime-scalar path cannot reproduce.
    """
    params = {"w1": jax.random.normal(rng, (37, 11), jnp.float32),
              "w2": {"a": jax.random.normal(rng, (130,), jnp.float32)}}
    grads = jax.tree.map(lambda p: p * 0.03 + 0.01, params)
    m = jax.tree.map(lambda p: jnp.full_like(p, 0.05), params)
    v = jax.tree.map(lambda p: jnp.full_like(p, 0.02), params)
    kw = dict(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, bc1=0.1, bc2=0.05)
    newp, newm, newv = ops.hfused_adamw(params, grads, m, v, **kw)
    for path in [("w1",), ("w2", "a")]:
        def get(t):
            for p in path:
                t = t[p]
            return t
        wp, wm, wv = ref.adamw(get(params), get(grads), get(m), get(v), **kw)
        np.testing.assert_allclose(np.asarray(get(newp)), np.asarray(wp),
                                   rtol=5e-6, atol=1e-8)
        np.testing.assert_allclose(np.asarray(get(newm)), np.asarray(wm),
                                   rtol=5e-6, atol=1e-8)
        np.testing.assert_allclose(np.asarray(get(newv)), np.asarray(wv),
                                   rtol=5e-6, atol=1e-8)
