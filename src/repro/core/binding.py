"""Binding registry — how a planned graph touches live arrays.

The planner decides WHAT fuses (core/planner.py) and the autotuner decides
HOW (core/autotuner.py); neither ever sees a real tensor.  The executor
(core/executor.py) closes that gap, and this module is its contract: a
``BindingRegistry`` maps each graph op's *named operands* (the stable
``OpSpec.in_names`` / ``out_names`` signature) onto getters and setters over
a **state pytree** — a flat ``dict[str, Array]`` threaded through the
program.  Dataflow between ops is expressed by key sharing (op A's output
slot writes the key op B's input slot reads), and framework glue (a QKV
projection between a norm and the attention that consumes it, a residual
add, a reshape into the optimizer's flat (R, 128) layout, the serve
engine's per-slot cache-position vector — RoPE at ``pos[b]``, a k/v
scatter into row ``pos[b]``, and the vectorized (B, 1) ``len`` operand
read as ``pos + 1`` — docs/serving.md) lives in the slots themselves —
pure-jnp closures, so a compiled program stays jittable.

Three slot forms, in increasing power:

  "key"                      — read/write ``state[key]`` verbatim.
  Slot(key, get=, put=)      — ``get(state[key]) -> array`` view on read;
                               ``put(state[key], new) -> value`` on write.
  Slot(get=, put=) (no key)  — whole-state forms: ``get(state) -> array``
                               and ``put(state, new) -> state``; this is
                               where inter-op glue lives.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping, Optional, Sequence

from repro.core.op_spec import OpSpec

State = dict


@dataclass(frozen=True)
class Slot:
    """One operand's route in and out of the state pytree."""
    key: Optional[str] = None
    get: Optional[Callable] = None
    put: Optional[Callable] = None

    def read(self, state: State):
        if self.key is None:
            if self.get is None:
                raise ValueError("input slot needs a key or a get()")
            return self.get(state)
        val = state[self.key]
        return self.get(val) if self.get is not None else val

    def write(self, state: State, new) -> State:
        if self.key is None:
            if self.put is None:
                raise ValueError("output slot needs a key or a put()")
            return self.put(state, new)
        state = dict(state)
        state[self.key] = (self.put(state.get(self.key), new)
                           if self.put is not None else new)
        return state


def _as_slot(s) -> Slot:
    if isinstance(s, Slot):
        return s
    if isinstance(s, str):
        return Slot(key=s)
    raise TypeError(f"operand binding must be a key string or Slot, got {s!r}")


class BindingRegistry:
    """Per-op operand-name -> Slot table, validated against OpSpec signatures.

    ``bind(op_name, **slots)`` binds input and output operands that share a
    name (in-place operands) to the same slot; ``bind(op_name,
    inputs={...}, outputs={...})`` splits them when reads and writes must
    route differently.
    """

    def __init__(self):
        self._inputs: dict[str, dict[str, Slot]] = {}
        self._outputs: dict[str, dict[str, Slot]] = {}

    def bind(self, op_name: str, inputs: Optional[Mapping] = None,
             outputs: Optional[Mapping] = None, **shared) -> "BindingRegistry":
        ins = {k: _as_slot(v) for k, v in {**shared, **(inputs or {})}.items()}
        outs = {k: _as_slot(v) for k, v in {**shared, **(outputs or {})}.items()}
        self._inputs.setdefault(op_name, {}).update(ins)
        self._outputs.setdefault(op_name, {}).update(outs)
        return self

    # ------------------------------------------------------------------
    def validate(self, op: OpSpec) -> None:
        """Every named operand of ``op`` must resolve to a slot."""
        if not op.has_signature:
            raise ValueError(
                f"op '{op.name}' has no operand signature "
                f"(OpSpec.in_names/out_names) — the executor cannot bind it")
        missing = [n for n in op.in_names
                   if n not in self._inputs.get(op.name, {})]
        missing += [f"{n} (out)" for n in op.out_names
                    if n not in self._outputs.get(op.name, {})]
        if missing:
            raise ValueError(
                f"op '{op.name}': unbound operands {missing} — "
                f"register them with BindingRegistry.bind()")

    def inputs(self, op: OpSpec, state: State) -> list:
        table = self._inputs[op.name]
        return [table[n].read(state) for n in op.in_names]

    def commit(self, op: OpSpec, state: State, outs: Sequence) -> State:
        table = self._outputs[op.name]
        for name, new in zip(op.out_names, outs):
            state = table[name].write(state, new)
        return state

    def describe(self, op: OpSpec) -> dict:
        def lab(slot: Slot, rw):
            fn = slot.get if rw == "r" else slot.put
            return (slot.key or "<computed>") + ("*" if fn else "")
        return {
            "inputs": {n: lab(self._inputs[op.name][n], "r")
                       for n in op.in_names},
            "outputs": {n: lab(self._outputs[op.name][n], "w")
                        for n in op.out_names},
        }


def default_bindings(ops: Sequence[OpSpec]) -> BindingRegistry:
    """One state key per (op, operand): ``"{op.name}.{operand}"``.  The
    no-dataflow registry — tests and benchmarks bind synthesized operands;
    real integrations share keys to wire producer -> consumer."""
    reg = BindingRegistry()
    for op in ops:
        reg.bind(op.name, **{n: f"{op.name}.{n}"
                             for n in (*op.in_names, *op.out_names)})
    return reg


def synth_state(ops: Sequence[OpSpec], seed: int = 0) -> State:
    """Random/zero buffers for every *input* operand under default keys
    (mirrors core/timing.synth_inputs, but keyed for the executor)."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(seed)
    state: State = {}
    for op in ops:
        for name, o in zip(op.in_names, op.inputs):
            k = f"{op.name}.{name}"
            if k in state:
                continue
            key, sub = jax.random.split(key)
            if jnp.issubdtype(jnp.dtype(o.dtype), jnp.floating):
                state[k] = jax.random.normal(sub, o.shape).astype(o.dtype) * 0.1
            else:
                state[k] = jnp.zeros(o.shape, o.dtype)
    return state
