"""Vertical (epilogue) stitching — a producer→consumer chain as ONE OpSpec.

The paper fuses *independent* kernels horizontally; FusionStitching and the
BLAS kernel-fusion line (PAPERS.md) show the orthogonal win: a producer
whose output feeds exactly one consumer elementwise/row-wise (rmsnorm→matmul,
matmul→residual-add, matmul→activation, dW-matmul→adamw) can run as one
kernel with the intermediate living in registers/VMEM instead of
round-tripping HBM.  Both compose: a stitched chain is just an OpSpec, so it
becomes one *member* of a horizontal bundle — one ratio coordinate for the
autotuner, one node for the planner, one set of external operands for the
executor.

Mechanics.  Every kernel body in this repo follows the single-assignment
block contract (``o_ref[...] = value``; stitched inputs are read as
``ref[...]``), so composition needs no codegen: the chain body runs the
producer with a stub output ref that *captures* the block value, then runs
the consumer with a stub input ref that *returns* it.  The producer's HBM
write and the consumer's HBM read of the intermediate both vanish from the
chain's ``hbm_bytes``; the live block is charged to ``extra_vmem_bytes`` so
the cost model's VMEM cliff still sees it.

Safety is ``can_stitch``: per-step block correspondence (identical blocks,
or the row-major reshape case dW→adamw needs), equal grids, matching dtypes,
collision-free merged operand names.  Graph-level legality (single reader,
contraction stays acyclic) is the planner's job — see
``planner._contract_chains``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp

from repro.core.op_spec import Operand, OpSpec, shrink_blocks

CHAIN_SEP = "→"                       # "→" — also how plans render chains


def chain_label(*names: str) -> str:
    return CHAIN_SEP.join(names)


# ---------------------------------------------------------------------------
# Stub refs — the register-resident intermediate
# ---------------------------------------------------------------------------
class _CaptureRef:
    """Output stub handed to the producer body: ``o_ref[...] = v`` lands the
    block value here instead of a VMEM window.  Exposes ``shape``/``dtype``
    (bodies do ``.astype(o_ref.dtype)`` for their final rounding — capturing
    *after* that cast is what makes the chain bit-identical to the
    unstitched pair)."""

    __slots__ = ("shape", "dtype", "value")

    def __init__(self, block_shape, dtype):
        self.shape = tuple(block_shape)
        self.dtype = jnp.dtype(dtype)
        self.value = None

    def __setitem__(self, idx, v):
        if idx is not Ellipsis:
            raise NotImplementedError(
                "stitched producer must write its whole block (o_ref[...])")
        self.value = v


class _ValueRef:
    """Input stub handed to the consumer body for the stitched operand:
    ``ref[...]`` returns the captured block value."""

    __slots__ = ("shape", "dtype", "value")

    def __init__(self, value):
        self.value = value
        self.shape = tuple(value.shape)
        self.dtype = value.dtype

    def __getitem__(self, idx):
        if idx is not Ellipsis:
            raise NotImplementedError(
                "stitched consumer must read its whole block (ref[...])")
        return self.value


# ---------------------------------------------------------------------------
# The stitchability contract
# ---------------------------------------------------------------------------
_PROBE_FAILED = object()


def _probe(operand: Operand, grid: int):
    """Index-map values at sample steps (incl. late steps — see
    op_spec._index_pattern for why grid-aware probes matter)."""
    steps = sorted({0, 1, 2, grid // 2, max(grid - 1, 0)})
    try:
        return {s: tuple(int(c) for c in operand.index_map(s))
                for s in steps}
    except Exception:
        return _PROBE_FAILED


def _row_stream(operand: Operand, grid: int) -> bool:
    """Pure row-stream: block covers every trailing dim and the map is
    s -> (s, 0, ..., 0) — step s holds rows [s*b0, (s+1)*b0), contiguous in
    row-major order.  Two such operands with equal per-block element counts
    see the *same elements* at every step, which is what licenses the
    flatten/reshape correspondence (dW (bm, N) blocks → adamw (bm*N/128,
    128) blocks)."""
    if operand.block_shape[1:] != operand.shape[1:]:
        return False
    probes = _probe(operand, grid)
    if probes is _PROBE_FAILED:
        return False
    return all(p == (s,) + (0,) * (len(operand.block_shape) - 1)
               for s, p in probes.items())


def _blocks_identical(a: Operand, b: Operand, grid: int) -> bool:
    if a.shape != b.shape or a.block_shape != b.block_shape:
        return False
    pa, pb = _probe(a, grid), _probe(b, grid)
    return pa is not _PROBE_FAILED and pa == pb


def can_stitch(producer: OpSpec, consumer: OpSpec,
               operand: str) -> Optional[str]:
    """None iff ``producer``'s output can feed ``consumer.<operand>``
    in-register; otherwise the reason it can't.  Checks the *kernel-level*
    contract only — the graph-level single-reader/acyclicity checks live in
    the planner."""
    if not (producer.has_signature and consumer.has_signature):
        return "both ops need operand signatures"
    if producer.chain or consumer.chain:
        return "chains do not cascade (one stitch level)"
    if len(producer.outputs) != 1:
        return f"producer has {len(producer.outputs)} outputs, need 1"
    if producer.out_names[0] in producer.in_names:
        return "producer output is in-place (cannot be eliminated)"
    if operand not in consumer.in_names:
        return f"consumer has no input named {operand!r}"
    if operand in consumer.out_names:
        return f"stitched operand {operand!r} is consumer in-place state"
    if producer.grid != consumer.grid:
        return f"grid mismatch: {producer.grid} vs {consumer.grid}"

    sidx = consumer.in_names.index(operand)
    pout, cin = producer.outputs[0], consumer.inputs[sidx]
    if jnp.dtype(pout.dtype) != jnp.dtype(cin.dtype):
        return f"dtype mismatch: {pout.dtype} vs {cin.dtype}"
    if math.prod(pout.shape) != math.prod(cin.shape):
        return f"element count mismatch: {pout.shape} vs {cin.shape}"
    if not (_blocks_identical(pout, cin, producer.grid)
            or (_row_stream(pout, producer.grid)
                and _row_stream(cin, consumer.grid)
                and math.prod(pout.block_shape)
                == math.prod(cin.block_shape))):
        return ("per-step block mismatch: "
                f"{pout.block_shape}@{pout.shape} vs "
                f"{cin.block_shape}@{cin.shape}")

    merged_in = producer.in_names + tuple(n for n in consumer.in_names
                                          if n != operand)
    if len(set(merged_in)) != len(merged_in):
        return f"operand name collision in merged signature: {merged_in}"
    return None


# ---------------------------------------------------------------------------
# Building the chain OpSpec
# ---------------------------------------------------------------------------
def _array_bytes(o: Operand) -> float:
    return float(math.prod(o.shape)) * jnp.dtype(o.dtype).itemsize


def stitch(producer: OpSpec, consumer: OpSpec, operand: str) -> OpSpec:
    """Contract producer→consumer into one OpSpec (``can_stitch`` must
    pass).  External operands only: the chain's inputs are the producer's
    plus the consumer's minus the stitched one; its outputs are the
    consumer's.  ``hbm_bytes`` drops the intermediate's write+read — the
    memory-traffic saving the cost model prices; the live block rides in
    ``extra_vmem_bytes`` so VMEM pressure is not understated."""
    reason = can_stitch(producer, consumer, operand)
    if reason is not None:
        raise ValueError(
            f"cannot stitch {producer.name}{CHAIN_SEP}{consumer.name}: "
            f"{reason}")

    sidx = consumer.in_names.index(operand)
    pout = producer.outputs[0]
    cin = consumer.inputs[sidx]
    n_pi, n_ci = len(producer.inputs), len(consumer.inputs)
    reshape_to = (None if pout.block_shape == cin.block_shape
                  else cin.block_shape)
    p_body, c_body = producer.body, consumer.body

    def body(step, *refs):
        pin = refs[:n_pi]
        cin_ext = refs[n_pi:n_pi + n_ci - 1]
        couts = refs[n_pi + n_ci - 1:]
        cap = _CaptureRef(pout.block_shape, pout.dtype)
        p_body(step, *pin, cap)
        if cap.value is None:
            raise RuntimeError(
                f"{producer.name}: body never wrote its output block")
        val = cap.value if reshape_to is None else cap.value.reshape(
            reshape_to)
        crefs = (*cin_ext[:sidx], _ValueRef(val), *cin_ext[sidx:])
        c_body(step, *crefs, *couts)

    def shrink(factor: int) -> Optional[OpSpec]:
        ps = shrink_blocks(producer, factor)
        cs = shrink_blocks(consumer, factor)
        if ps is None or cs is None or can_stitch(ps, cs, operand):
            return None
        return stitch(ps, cs, operand)

    saved = _array_bytes(pout) + _array_bytes(cin)
    tag = "|".join(t for t in (producer.tag, consumer.tag) if t)
    return OpSpec(
        name=f"{producer.name}{CHAIN_SEP}{consumer.name}",
        grid=producer.grid,
        body=body,
        inputs=producer.inputs + consumer.inputs[:sidx]
        + consumer.inputs[sidx + 1:],
        outputs=consumer.outputs,
        flops=producer.flops + consumer.flops,
        hbm_bytes=max(producer.hbm_bytes + consumer.hbm_bytes - saved, 1.0),
        tag=f"chain:{tag}" if tag else "chain",
        shrink=shrink,
        in_names=producer.in_names + consumer.in_names[:sidx]
        + consumer.in_names[sidx + 1:],
        out_names=consumer.out_names,
        chain=(producer.name, consumer.name),
        extra_vmem_bytes=pout.block_bytes(),
    )
