"""N-way horizontal fusion bundles: generate()/cost-model/autotuner/planner
over Sequence[OpSpec], the 2-op compatibility surface, and the N-way
multi-tensor Adam path.  (Deliberately hypothesis-free so this coverage
survives environments without the property-testing extra.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotuner, hfuse, planner
from repro.core.cost_model import (Schedule, bundle_profitable, hfused_cost,
                                   native_time, ratio_candidates)
from repro.kernels import paper_suite as ps


def _bundle(names):
    return ps.make_bundle(names, small=True)


def _check_bundle(names, sched, tol=2e-3):
    """Fused bundle output == each member's standalone run_single output."""
    ops, mks, _ = _bundle(names)
    xs = [mk(jax.random.PRNGKey(i)) for i, mk in enumerate(mks)]
    fused = hfuse.generate(ops, sched, interpret=True)
    outs = fused(*[a for x in xs for a in x])
    off = 0
    for op, x in zip(ops, xs):
        want = hfuse.run_single(op, interpret=True)(*x)
        for o in want:
            np.testing.assert_allclose(np.asarray(outs[off], np.float32),
                                       np.asarray(o, np.float32),
                                       rtol=tol, atol=tol)
            off += 1
    assert off == len(outs)


@pytest.mark.parametrize("ratios", [(1, 1, 1), (2, 1, 3), (4, 2, 1)])
def test_three_way_fused_matches_run_single(ratios):
    _check_bundle(("maxpool", "upsample", "sha_like"), Schedule(ratios))


@pytest.mark.parametrize("names", ps.paper_triples())
def test_all_registered_triples_fuse_correctly(names):
    _check_bundle(names, Schedule((1,) * len(names)))


def test_four_way_bundle():
    _check_bundle(("maxpool", "bnstats", "upsample", "sha_like"),
                  Schedule((1, 2, 1, 2)))


def test_two_op_api_unchanged():
    """The legacy pairwise surface: generate(a, b, sched), Schedule(ra, rb),
    generate_vfused(a, b), run_native(a, b)."""
    opA, mkA, refA = ps.make_upsample(R=256, C=128, bm=64)
    opB, mkB, refB = ps.make_sha_like(R=256, bm=64)
    xa, xb = mkA(jax.random.PRNGKey(0)), mkB(jax.random.PRNGKey(1))
    sched = Schedule(2, 1)
    assert (sched.ra, sched.rb, sched.period) == (2, 1, 3)
    fused = hfuse.generate(opA, opB, sched, interpret=True)
    outs = fused(*xa, *xb)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(refA(*xa)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(refB(*xb)),
                               rtol=1e-4, atol=1e-4)
    vf = hfuse.generate_vfused(opA, opB, interpret=True)
    np.testing.assert_allclose(np.asarray(vf(*xa, *xb)[0]),
                               np.asarray(refA(*xa)), rtol=1e-4, atol=1e-4)
    nat = hfuse.run_native(opA, opB, interpret=True)
    np.testing.assert_allclose(np.asarray(nat(*xa, *xb)[1]),
                               np.asarray(refB(*xb)), rtol=1e-4, atol=1e-4)


def test_schedule_forms_equivalent():
    assert Schedule(3, 2) == Schedule((3, 2))
    assert Schedule((1, 2, 3)).offsets() == (0, 1, 3)
    assert Schedule((1, 2, 3)).period == 6
    with pytest.raises(ValueError):
        Schedule((1, 0))


def test_cost_model_nway_reduces_to_pairwise():
    a, _, _ = ps.make_ethash_like(R_dag=8192, bm=256)
    b, _, _ = ps.make_blake_like(R=2048, bm=256)
    for ra, rb in [(1, 1), (3, 2), (8, 1)]:
        e2 = hfused_cost(a, b, Schedule(ra, rb))
        en = hfused_cost([a, b], Schedule((ra, rb)))
        assert e2.t_hfused == en.t_hfused
        assert e2.t_native == en.t_native
        assert e2.vmem_bytes == en.vmem_bytes


def test_cost_model_three_way_bounds():
    """Engine-sum lower bound and serial upper bound hold for bundles."""
    ops, _, _ = _bundle(("ethash_like", "hist", "blake_like"))
    est = hfused_cost(ops, Schedule((1, 1, 1)))
    lower = max(sum(o.t_compute for o in ops), sum(o.t_memory for o in ops))
    if est.vmem_ok:
        assert est.t_hfused >= lower * 0.999
        assert est.t_hfused <= sum(native_time(o) for o in ops) * 1.001


def test_bundle_profitability_scenarios():
    mem, _, _ = ps.make_upsample()
    mem2, _, _ = ps.make_maxpool()
    c1, _, _ = ps.make_sha_like()
    c2, _, _ = ps.make_blake_like()
    assert bundle_profitable([mem, mem2, c1])
    assert not bundle_profitable([c1, c2])        # Blake256+SHA256, N-way
    # the mixed triple gains from genuine engine overlap (beyond the launch
    # amortization any one-kernel form gets); the all-compute triple gains
    # NOTHING from interleaving — the paper's §IV-C negative, N-way
    c3, _, _ = ps.make_blake2b_like()
    mixed = hfused_cost([mem, mem2, c1], Schedule((1, 1, 1)))
    same = hfused_cost([c1, c2, c3], Schedule((1, 1, 1)))
    assert mixed.gain_vs_vfused > 0
    assert same.gain_vs_vfused <= 1e-12
    assert mixed.speedup_pct() > 5.0


def test_ratio_candidates_nway():
    ops, _, _ = _bundle(("maxpool", "upsample", "sha_like"))
    cands = ratio_candidates(ops)
    assert all(c.n_ops == 3 for c in cands)
    assert Schedule((1, 1, 1)) in cands
    assert len(cands) >= 4
    # legacy two-positional form still works
    pair = ratio_candidates(ops[0], ops[2])
    assert all(c.n_ops == 2 for c in pair)


def test_autotuner_searches_bundles():
    ops, mks, _ = _bundle(("ethash_like", "hist", "blake_like"))
    res = autotuner.search(tuple(ops))
    assert res.best.est.t_hfused == min(c.est.t_hfused for c in res.log)
    assert len(res.log) >= 4
    assert res.ops == tuple(ops)
    fused = res.build(interpret=True)
    xs = [mk(jax.random.PRNGKey(i)) for i, mk in enumerate(mks)]
    outs = fused(*[a for x in xs for a in x])
    want = hfuse.run_single(ops[0], interpret=True)(*xs[0])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(want[0]),
                               rtol=2e-3, atol=2e-3)


def test_planner_emits_three_way_bundle():
    """A graph of 2 memory + 2 compute ops packs into a ≥3-way bundle when
    allowed, and the bundle mixes bound kinds."""
    graph = []
    for f in (ps.make_ethash_like, ps.make_upsample, ps.make_sha_like,
              ps.make_blake_like):
        op, _, _ = f()
        graph.append(planner.GraphOp(op))
    plan = planner.plan(graph, max_ways=3)
    widths = [len(d.members) for d in plan.fused]
    assert max(widths) >= 3
    big = next(d for d in plan.fused if len(d.members) >= 3)
    bounds = {op.op.bound for op in graph if op.op.name in big.members}
    assert bounds == {"compute", "memory"}
    assert big.result.best.sched.n_ops == len(big.members)


def test_planner_pairwise_default_unchanged():
    graph = []
    for f in (ps.make_ethash_like, ps.make_upsample, ps.make_sha_like,
              ps.make_blake_like):
        op, _, _ = f()
        graph.append(planner.GraphOp(op))
    plan = planner.plan(graph)                     # max_ways defaults to 2
    assert all(len(d.members) == 2 for d in plan.fused)
    assert set().union(*(d.members for d in plan.fused)) >= \
        {"ethash_like", "upsample"}


def test_planner_bundle_respects_dependencies():
    a, _, _ = ps.make_upsample()
    b, _, _ = ps.make_maxpool()
    c, _, _ = ps.make_sha_like()
    g = [planner.GraphOp(a), planner.GraphOp(c, deps=frozenset({a.name})),
         planner.GraphOp(b)]
    plan = planner.plan(g, max_ways=3)
    for d in plan.fused:
        assert not ({a.name, c.name} <= set(d.members))


def test_multi_tensor_adam_nway():
    """Each tensor its own OpSpec, one fused launch, matches leaf refs."""
    from repro.kernels import ops as kops
    from repro.kernels import ref
    kops.force("interpret")
    try:
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (50, 7)),
                  "b": jax.random.normal(jax.random.PRNGKey(1), (33,)),
                  "e": {"t": jax.random.normal(jax.random.PRNGKey(2), (260,))}}
        grads = jax.tree.map(lambda p: p * 0.02 + 0.003, params)
        m = jax.tree.map(lambda p: jnp.full_like(p, 0.01), params)
        v = jax.tree.map(lambda p: jnp.full_like(p, 0.04), params)
        kw = dict(lr=3e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
                  bc1=0.2, bc2=0.1)
        newp, newm, newv = kops.hfused_adamw(params, grads, m, v, **kw)
        flat_new, _ = jax.tree.flatten((newp, newm, newv))
        assert all(jnp.all(jnp.isfinite(l)) for l in flat_new)
        lp, td = jax.tree.flatten(params)
        for i, (p, g, mm, vv) in enumerate(zip(
                lp, td.flatten_up_to(grads), td.flatten_up_to(m),
                td.flatten_up_to(v))):
            wp, wm, wv = ref.adamw(p, g, mm, vv, **kw)
            np.testing.assert_allclose(
                np.asarray(td.flatten_up_to(newp)[i]), np.asarray(wp),
                rtol=5e-6, atol=1e-8)
            np.testing.assert_allclose(
                np.asarray(td.flatten_up_to(newm)[i]), np.asarray(wm),
                rtol=5e-6, atol=1e-8)
    finally:
        kops.force(None)
