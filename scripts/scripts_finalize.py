"""Finalize: inject the generated roofline markdown table into EXPERIMENTS.md
(replacing the <!-- ROOFLINE_TABLE --> marker) and print headline stats.

  PYTHONPATH=src python scripts/scripts_finalize.py
"""
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

import json

from benchmarks import roofline

rows = roofline.build_table()
md = roofline.markdown(rows)
(ROOT / "artifacts" / "roofline_table.json").write_text(json.dumps(rows, indent=1, default=float))

exp = ROOT / "EXPERIMENTS.md"
text = exp.read_text()
marker = "<!-- ROOFLINE_TABLE -->"
start = text.index(marker)
# replace everything from the marker to EOF (or next header)
text = text[: start + len(marker)] + "\n\n" + md + "\n"
exp.write_text(text)

ok = [r for r in rows if r["status"] == "OK"]
dom = {}
for r in ok:
    dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
print(f"cells OK: {len(ok)}; SKIP: {sum(r['status'] == 'SKIP' for r in rows)}; "
      f"other: {sum(r['status'] not in ('OK', 'SKIP') for r in rows)}")
print("dominant terms:", dom)
exact = sum(1 for r in ok if r.get("exact"))
print(f"exact (unrolled-extrapolated) cells: {exact}/{len(ok)}")
best = sorted(ok, key=lambda r: -r["roofline_fraction"])[:5]
for r in best:
    print(f"  best MFU-bound: {r['arch']} {r['shape']} "
          f"{100 * r['roofline_fraction']:.0f}%")
