"""The paper's core claim, as an executable invariant: the horizontally
fused kernel is FUNCTIONALLY EQUIVALENT to running the two kernels natively,
for every thread-space partition (schedule).  Property-tested with hypothesis
over schedules and shapes; plus cost-model scenario checks (§IV-C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (see "
                           "requirements.txt); non-property N-way coverage "
                           "lives in test_hfuse_nway.py")
from hypothesis import given, settings, strategies as st

from repro.core import autotuner, hfuse, planner
from repro.core.cost_model import Schedule, fusion_profitable, hfused_cost
from repro.kernels import paper_suite as ps


def _check_pair(opA, mkA, refA, opB, mkB, refB, sched, tol=1e-4):
    xa = mkA(jax.random.PRNGKey(0))
    xb = mkB(jax.random.PRNGKey(1))
    fused = hfuse.generate(opA, opB, sched, interpret=True)
    outs = fused(*xa, *xb)
    wa, wb = refA(*xa), refB(*xb)
    wa = wa if isinstance(wa, tuple) else (wa,)
    wb = wb if isinstance(wb, tuple) else (wb,)
    for got, want in zip(outs, (*wa, *wb)):
        np.testing.assert_allclose(np.asarray(got, np.float32)[..., :1],
                                   np.asarray(want, np.float32)[..., :1],
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("ra,rb", [(1, 1), (2, 1), (1, 3), (4, 2)])
def test_fused_equivalence_mixed_pair(ra, rb):
    opA, mkA, refA = ps.make_upsample(R=512, C=128, bm=128)
    opB, mkB, refB = ps.make_sha_like(R=512, C=128, bm=128)
    _check_pair(opA, mkA, refA, opB, mkB, refB, Schedule(ra, rb))


@pytest.mark.parametrize("a,b", ps.paper_pairs())
def test_all_16_paper_pairs_fuse_correctly(a, b):
    """Every Fig. 7 pair: fused == native at schedule 1:1 (reduced sizes)."""
    small = dict(
        maxpool=dict(R=256, C=128, bm=64),
        bnstats=dict(R=256, C=128, bm=64),
        upsample=dict(R=256, C=128, bm=64),
        im2col=dict(R=256, C=128, bm=64),
        hist=dict(R=256, C=128, bm=32),
        ethash_like=dict(R_dag=512, bm=128),
        sha_like=dict(R=256, bm=64),
        blake_like=dict(R=256, bm=64),
        blake2b_like=dict(R=256, bm=64),
    )
    opA, mkA, refA = ps.ALL_KERNELS[a](**small[a])
    opB, mkB, refB = ps.ALL_KERNELS[b](**small[b])
    _check_pair(opA, mkA, refA, opB, mkB, refB, Schedule(1, 1), tol=2e-3)


@settings(max_examples=12, deadline=None)
@given(ra=st.integers(1, 5), rb=st.integers(1, 5),
       bmA=st.sampled_from([64, 128]), seed=st.integers(0, 2 ** 20))
def test_fused_equivalence_property(ra, rb, bmA, seed):
    """Property: ANY interleave ratio and block size is equivalence-preserving
    (the paper's Generate() correctness condition)."""
    opA, mkA, refA = ps.make_maxpool(R=512, C=128, bm=bmA)
    opB, mkB, refB = ps.make_blake_like(R=256, C=128, bm=64)
    xa = mkA(jax.random.PRNGKey(seed))
    xb = mkB(jax.random.PRNGKey(seed + 1))
    fused = hfuse.generate(opA, opB, Schedule(ra, rb), interpret=True)
    outs = fused(*xa, *xb)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(refA(*xa)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(outs[1], np.float32),
                               np.asarray(refB(*xb), np.float32),
                               rtol=1e-4, atol=1e-4)


def test_vfused_equivalence():
    opA, mkA, refA = ps.make_bnstats(R=512, C=128, bm=128)
    opB, mkB, refB = ps.make_hist(R=256, C=128, bm=64)
    xa = mkA(jax.random.PRNGKey(0))
    xb = mkB(jax.random.PRNGKey(1))
    fused = hfuse.generate_vfused(opA, opB, interpret=True)
    outs = fused(*xa, *xb)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(refA(*xa)),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(refB(*xb)),
                               atol=0.5)


# ---------------------------------------------------------------------------
# cost model scenario structure (paper §IV-C)
# ---------------------------------------------------------------------------
def test_mixed_pair_profits_similar_pair_does_not():
    up, _, _ = ps.make_upsample()
    sha, _, _ = ps.make_sha_like()
    blake, _, _ = ps.make_blake_like()
    assert fusion_profitable(up, sha)            # Ethash+Blake256 scenario
    assert not fusion_profitable(sha, blake)     # Blake256+SHA256 scenario
    mixed = hfused_cost(up, sha, Schedule(1, 1))
    same = hfused_cost(sha, blake, Schedule(1, 1))
    assert mixed.speedup_pct() > same.speedup_pct()
    assert mixed.speedup_pct() > 5.0


@settings(max_examples=25, deadline=None)
@given(ra=st.integers(1, 8), rb=st.integers(1, 8))
def test_cost_model_bounds_property(ra, rb):
    """t_hfused is never better than the engine-sum lower bound and never
    worse than serial execution (when VMEM fits)."""
    a, _, _ = ps.make_ethash_like(R_dag=8192, bm=256)
    b, _, _ = ps.make_blake_like(R=2048, bm=256)
    from repro.core.cost_model import native_time
    est = hfused_cost(a, b, Schedule(ra, rb))
    lower = max(a.t_compute + b.t_compute, a.t_memory + b.t_memory)
    if est.vmem_ok:
        assert est.t_hfused >= lower * 0.999
        assert est.t_hfused <= (native_time(a) + native_time(b)) * 1.001


def test_autotuner_finds_best_logged_candidate():
    a, _, _ = ps.make_ethash_like(R_dag=16384, bm=512)
    b, _, _ = ps.make_blake_like(R=4096, bm=512)
    res = autotuner.search((a, b))
    assert res.best.est.t_hfused == min(c.est.t_hfused for c in res.log)
    assert res.best.est.speedup_pct() > 0
    assert len(res.log) >= 4                      # actually searched


def test_planner_pairs_and_rejections():
    ops_list = []
    for f in [ps.make_ethash_like, ps.make_upsample, ps.make_sha_like,
              ps.make_blake_like, ps.make_blake2b_like]:
        op, _, _ = f()
        ops_list.append(planner.GraphOp(op))
    plan = planner.plan(ops_list)
    fused_names = {frozenset(d.members) for d in plan.fused}
    # both memory-bound ops get compute partners
    assert any("ethash_like" in p for p in fused_names)
    assert any("upsample" in p for p in fused_names)
    # never fuses two compute kernels together
    for pair in fused_names:
        bounds = {("compute" if "sha" in n or "blake" in n else "memory")
                  for n in pair}
        assert bounds == {"compute", "memory"}


def test_planner_respects_dependencies():
    a, _, _ = ps.make_upsample()
    b, _, _ = ps.make_sha_like()
    g = [planner.GraphOp(a), planner.GraphOp(b, deps=frozenset({a.name}))]
    plan = planner.plan(g)
    assert not plan.fused                         # dependent: must not fuse
