"""Compiled-HLO analysis: roofline terms from the dry-run artifact.

Sources:
  * ``compiled.cost_analysis()``  -> HLO FLOPs + bytes accessed (per-device
    program after SPMD partitioning).
  * ``compiled.as_text()``        -> post-partitioning HLO; we sum the
    *bytes-on-wire per chip* of every collective (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), applying standard
    bidirectional-ring factors per op kind and the replica-group size parsed
    from the op.

TPU v5e hardware constants (targets; this container is CPU-only):
  197 TFLOP/s bf16 / chip, 819 GB/s HBM, ~50 GB/s/link ICI, ~128MiB VMEM.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-chip effective budget)
VMEM_BYTES = 128 * 2 ** 20
RIDGE = PEAK_FLOPS / HBM_BW  # ~240 flop/byte

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "tuple": 0, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all shapes in an HLO result-type string."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        g = m.group(1).strip()
        return len(g.split(",")) if g else default
    return default


@dataclasses.dataclass
class CollectiveStats:
    bytes_on_wire: float = 0.0          # per chip
    by_kind: dict = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, b: float):
        self.bytes_on_wire += b
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + b
        self.count += 1


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Per-chip bytes-on-wire summed over all collectives in the module.

    Ring factors (n = replica-group size):
      all-gather        out_bytes * (n-1)/n      (each chip receives the rest)
      reduce-scatter    in_bytes  * (n-1)/n
      all-reduce        2 * size  * (n-1)/n      (RS + AG)
      all-to-all        size      * (n-1)/n
      collective-permute  size                    (send + recv one hop)
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%name = TYPE[dims] op-name(...)" — the register name may itself
        # contain the op name, so split on ' = ' first.
        if " = " not in s:
            continue
        rhs = s.split(" = ", 1)[1]
        kind = None
        for k in COLLECTIVE_KINDS:
            i = rhs.find(f" {k}(")
            j = rhs.find(f" {k}-start(")
            if i >= 0 or j >= 0:
                kind = k
                rhs_shape = rhs[: i if i >= 0 else j]
                break
        if kind is None:
            continue
        size = _shape_bytes(rhs_shape)
        if size == 0:
            continue
        n = _group_size(s, n_devices)
        if n <= 1:
            continue
        frac = (n - 1) / n
        if kind == "all-reduce":
            b = 2.0 * size * frac
        elif kind == "collective-permute":
            b = float(size)
        else:
            b = size * frac
        stats.add(kind, b)
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float                 # per chip
    bytes_hbm: float             # per chip
    coll_bytes: float            # per chip, on-wire
    n_devices: int
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.t_compute = self.flops / PEAK_FLOPS
        self.t_memory = self.bytes_hbm / HBM_BW
        self.t_collective = self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Best-case step time assuming perfect overlap of the three engines."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def summary(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_hbm,
            "coll_bytes_per_chip": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "t_bound_s": self.t_bound,
            "coll_by_kind": self.coll_by_kind,
        }


def analyze_compiled(compiled, n_devices: int) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    stats = collective_bytes(compiled.as_text(), n_devices)
    r = Roofline(flops=flops, bytes_hbm=byts, coll_bytes=stats.bytes_on_wire,
                 n_devices=n_devices)
    r.coll_by_kind = stats.by_kind
    return r


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                      # CPU backend may not support
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_bytes"] = (out.get("argument_size_in_bytes", 0)
                              + out.get("output_size_in_bytes", 0)
                              + out.get("temp_size_in_bytes", 0)
                              - out.get("alias_size_in_bytes", 0))
    return out
