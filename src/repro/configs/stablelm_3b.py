"""StableLM-3B — dense MHA transformer [hf:stabilityai/stablelm-2-1_6b lineage; unverified]

32 layers, d_model 2560, 32 heads (kv=32, i.e. full MHA), d_ff 6912,
vocab 50304, partial-rotary RoPE (25%), LayerNorm.
"""
from repro.configs.base import ModelConfig, register


@register("stablelm-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        family="dense",
        num_layers=32,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab_size=50_304,
        activation="silu",
        norm="layernorm",
        rope_fraction=0.25,
        source="[hf:stabilityai; unverified] dense MHA",
    )
