"""MusicGen-medium — decoder-only LM over EnCodec tokens [arXiv:2306.05284; hf]

48 layers, d_model 1536, 24 heads (MHA kv=24), d_ff 6144 (gelu MLP),
vocab 2048 per codebook, 4 parallel codebooks (delay interleaving pattern).
The EnCodec frontend is a stub per assignment: ``input_specs`` supplies
precomputed frame embeddings / codebook token ids.
"""
from repro.configs.base import ModelConfig, register


@register("musicgen-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        activation="gelu_mlp",
        norm="layernorm",
        frontend="audio_stub",
        num_codebooks=4,
        source="[arXiv:2306.05284; hf] decoder-only over EnCodec tokens",
    )
