"""Batched serving engine (iteration-level batching with refill).

Semantics: up to ``batch`` requests run in lock-step — prompts are
right-aligned/padded, prefilled with the batched ``lm.prefill``, then decoded
together; finished sequences are masked out and the batch refills at the next
wavefront.  Per-slot-position continuous batching would need a vectorized
cache position (B,) — noted as an extension in DESIGN.md; iteration-level
batching is what the assigned decode shapes (uniform context length) model.

On the production mesh the cache is sequence-sharded and decode attention is
the distributed flash-decode (DESIGN.md §7).  ``examples/dual_stream_decode.py``
shows the horizontal-fusion dual-stream variant of the decode step.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_token: Optional[int] = None
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch: int = 8,
                 max_len: int = 512, rng_seed: int = 0,
                 plan_fusion: bool = False, measure=None,
                 schedule_cache=None):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.rng = jax.random.PRNGKey(rng_seed)
        self._decode = jax.jit(
            lambda p, c, t: lm.decode_step(cfg, p, c, t))
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(cfg, p, b, max_len=self.max_len))
        self.fusion_plan = (self.plan_decode_fusion(
            measure=measure, cache=schedule_cache) if plan_fusion else None)

    # ------------------------------------------------------------------
    def plan_decode_fusion(self, *, max_ways: int = 3, prefill_chunk: int = 2048,
                           measure=None, cache=None):
        """Register the serving step's ops as a planner graph (ROADMAP):
        decode-wave RMSNorm + decode attention + the router/FFN projection,
        plus a prefill-chunk FFN matmul — the compute-bound partner of the
        chunked-prefill⊕decode overlap mode (benchmarks/fig_framework).
        ``planner.plan(max_ways=3)`` decides the bundle; with ``measure``
        the schedule is profiled, and ``cache`` makes every later engine
        start skip the search entirely.
        """
        from repro.core import planner
        from repro.kernels.decode_attention import decode_attention_op
        from repro.kernels.matmul import matmul_1d_op
        from repro.kernels.rmsnorm import rmsnorm_op

        cfg = self.cfg
        d, H, Hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
        D = cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        S = max(128, -(-self.max_len // 128) * 128)     # cache, 128-aligned
        B = self.batch

        norm = rmsnorm_op(R=B, d=d, dtype=dt, bm=B)
        # largest 128-multiple chunk <= 1024 that divides S (S is 128-aligned,
        # so the scan bottoms out at ck=128)
        ck = next(c for c in range(min(1024, S), 0, -128) if S % c == 0)
        att = decode_attention_op(B=B, S=S, H=H, Hkv=Hkv, D=D, dtype=dt,
                                  ck=ck)
        # decode-wave projection: MoE router when the model routes, else the
        # FFN up-projection — weight streaming dominates at serving batch
        # (memory-bound; the honest fig_framework finding), so the planner
        # pairs it with the prefill chunk's genuinely compute-bound matmul.
        n_out = cfg.moe.num_experts if cfg.moe is not None else max(cfg.d_ff, d)
        proj = matmul_1d_op(M=B, K=d, N=n_out, dtype=dt, bm=B)
        proj = dataclasses.replace(
            proj, name="moe_router" if cfg.moe is not None else "ffn_proj")
        # decode-step dataflow: norm -> attention -> router/FFN; proj reads
        # the POST-attention hidden state, so it can never fuse with att —
        # the only legal cross-stream partner is the prefill chunk
        graph = [planner.GraphOp(norm),
                 planner.GraphOp(att, deps=frozenset({norm.name})),
                 planner.GraphOp(proj, deps=frozenset({norm.name,
                                                       att.name}))]
        if prefill_chunk:
            pf = matmul_1d_op(M=prefill_chunk, K=d, N=max(cfg.d_ff, d),
                              dtype=dt, bm=min(128, prefill_chunk))
            pf = dataclasses.replace(pf, name="prefill_ffn")
            graph.append(planner.GraphOp(pf))
        return planner.plan(graph, max_ways=max_ways, measure=measure,
                            cache=cache)

    # ------------------------------------------------------------------
    def _prefill_wave(self, wave: list[Request]):
        """Waves are grouped by prompt length (see run()); empty slots
        duplicate row 0 and are ignored."""
        S = len(wave[0].prompt)
        toks = np.zeros((self.batch, S), np.int32)
        for i, r in enumerate(wave):
            toks[i] = r.prompt
        cache, last_logits = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        return cache, last_logits

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        if req.temperature > 0:
            self.rng, sub = jax.random.split(self.rng)
            return int(jax.random.categorical(
                sub, jnp.asarray(logits) / req.temperature))
        return int(logits.argmax())

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        # group by prompt length: one wave = one (length, <=batch) group
        by_len: dict[int, list[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        pending: list[list[Request]] = []
        for _, group in sorted(by_len.items()):
            for i in range(0, len(group), self.batch):
                pending.append(group[i: i + self.batch])
        while pending:
            wave = pending.pop(0)
            cache, last_logits = self._prefill_wave(wave)
            logits = np.asarray(last_logits, np.float32)
            for i, r in enumerate(wave):
                r.out_tokens.append(self._sample(logits[i], r))
            budget = max(r.max_new_tokens for r in wave)
            for _ in range(budget - 1):
                if all(r.done or len(r.out_tokens) >= r.max_new_tokens
                       for r in wave):
                    break
                toks = np.zeros((self.batch,), np.int32)
                for i, r in enumerate(wave):
                    toks[i] = r.out_tokens[-1]
                out, cache = self._decode(self.params, cache,
                                          jnp.asarray(toks))
                logits = np.asarray(out, np.float32)
                for i, r in enumerate(wave):
                    if r.done or len(r.out_tokens) >= r.max_new_tokens:
                        continue
                    tok = self._sample(logits[i], r)
                    r.out_tokens.append(tok)
                    if r.eos_token is not None and tok == r.eos_token:
                        r.done = True
            for r in wave:
                r.done = True
        return requests
