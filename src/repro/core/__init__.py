"""The paper's contribution: automatic horizontal fusion for TPU/Pallas.

op_spec        — fusible-op IR (1-D grid + BlockSpecs + resource profile)
                 + shrink_blocks (auto block-shrink, the register-cap move)
cost_model     — 3-term roofline scoring (the napkin-math engine)
hfuse          — Generate(): the fused pallas_call builder (+ vfuse baseline)
autotuner      — Main(): two-stage top-K + coordinate-descent search (Fig. 6)
planner        — graph-level bundling of memory-bound x compute-bound ops
timing         — make_measure(): the profiler Main() scores candidates with
schedule_cache — persistent tuned-schedule store (never re-search a bundle)
"""
from repro.core import (autotuner, cost_model, hfuse, op_spec,  # noqa: F401
                        planner, schedule_cache, timing)
