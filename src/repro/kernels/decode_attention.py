"""Decode attention Pallas kernel: one new token vs a long KV cache.

Memory-bound par excellence (streams the whole cache, does O(D) flops per
byte) — the framework's Ethash: the canonical horizontal-fusion partner for
compute-bound matmuls in the dual-stream decode mode (serve/dual_stream.py).

Fusible form: 1-D grid over (batch, kv-chunk) linearized; the online-softmax
(m, l) carries live in small fp32 *outputs* with constant index maps (not
scratch) so the op composes under core/hfuse.generate.

Paged form (``block_table=(num_blocks, block_size)``): the k/v operands are
a flat block arena ``(num_blocks, block_size, Hkv, D)`` shared by every
slot, and a per-slot block table rides as one more small int32 operand
("bt", ``(B, max_blocks)``, fetched batch-major like "len").  Each kv-chunk
step gathers its ``ck // block_size`` pages from the arena by table lookup
— the memory-intensive indirection the serve engine pairs with
compute-bound GEMMs in one fused launch (serve/kv_pool.py owns the arena).
The page gather reassembles exactly the contiguous kernel's ``(ck, Hkv,
D)`` block, so paged and contiguous attention are BITWISE equal for equal
logical cache content (tests/test_kv_paged_attention.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.op_spec import MIN_BLOCK_ROWS, OpSpec, Operand

NEG_INF = -1e30


def gather_pages(ref, bt, first_page: int, npages: int):
    """Assemble one (npages * block_size, ...) kv-chunk from the arena
    ``ref`` by looking pages ``bt[first_page : first_page + npages]`` up in
    the (already loaded) block-table row ``bt``.  ``first_page`` may be a
    traced scalar; ``npages`` is static."""
    pages = [ref[pl.ds(bt[first_page + p], 1)][0] for p in range(npages)]
    return pages[0] if npages == 1 else jnp.concatenate(pages, axis=0)


def decode_attention_op(B: int, S: int, H: int, Hkv: int, D: int,
                        dtype=jnp.bfloat16, ck: int = 1024,
                        length=None, dynamic_length: bool = False,
                        block_table=None) -> OpSpec:
    """q: (B,H,D); cache k,v: (B,S,Hkv,D); out o: (B,H,D) fp32.

    Grid: B * (S // ck) steps, batch-major.  `length` (static) masks the
    valid cache prefix; None = full cache.  ``dynamic_length`` instead adds
    a tiny (B, 1) int32 operand ("len", one row per batch slot, fetched as a
    (1, 1) block by the batch-major index map) holding each slot's valid
    prefix, so one compiled kernel serves every decode position of every
    slot independently — the form the executor binds to a live per-slot
    ``pos + 1`` vector (continuous batching: slots advance, finish and
    refill at unrelated cache positions within one launch).

    ``block_table=(num_blocks, block_size)`` switches to the paged form:
    k/v become the shared ``(num_blocks, block_size, Hkv, D)`` arena
    (constant index map — the gather is in-body, since fused index maps are
    pure functions of the grid step), ``S`` becomes the per-slot LOGICAL
    capacity (``max_blocks = S // block_size`` table columns), and a
    ``(B, max_blocks)`` int32 operand ("bt") fetched batch-major maps each
    slot's logical pages to arena blocks.  Requires ``ck % block_size == 0``
    so every kv-chunk is a whole number of pages.
    """
    assert S % ck == 0 and H % Hkv == 0
    assert not (dynamic_length and length is not None)
    nk = S // ck
    rep = H // Hkv
    scale = 1.0 / math.sqrt(D)
    valid_len = S if length is None else int(length)
    if block_table is not None:
        num_blocks, bs = block_table
        assert ck % bs == 0 and S % bs == 0
        max_blocks = S // bs
        npc = ck // bs                       # pages per kv-chunk

    def body(step, *refs):
        if block_table is not None:
            bt_ref, refs = refs[0], refs[1:]
        if dynamic_length:
            len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref = refs
            cur_len = len_ref[0, 0]
        else:
            q_ref, k_ref, v_ref, o_ref, m_ref, l_ref = refs
            cur_len = valid_len
        j = step % nk

        @pl.when(j == 0)
        def _():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            o_ref[...] = jnp.zeros_like(o_ref)

        q = q_ref[0].astype(jnp.float32) * scale          # (H, D)
        if block_table is not None:
            bt = bt_ref[0]                                # (max_blocks,)
            k = gather_pages(k_ref, bt, j * npc, npc).astype(jnp.float32)
            v = gather_pages(v_ref, bt, j * npc, npc).astype(jnp.float32)
        else:
            k = k_ref[0].astype(jnp.float32)              # (ck, Hkv, D)
            v = v_ref[0].astype(jnp.float32)
        qg = q.reshape(Hkv, rep, D)
        s = jnp.einsum("hrd,khd->hrk", qg, k)             # (Hkv, rep, ck)
        kpos = j * ck + jax.lax.broadcasted_iota(jnp.int32, (Hkv, rep, ck), 2)
        s = jnp.where(kpos < cur_len, s, NEG_INF)
        m_prev = m_ref[0]                                 # (H, 1)
        m_new = jnp.maximum(m_prev, s.reshape(H, ck).max(-1, keepdims=True))
        p = jnp.exp(s.reshape(H, ck) - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * alpha + p.sum(-1, keepdims=True)
        pv = jnp.einsum("hrk,khd->hrd", p.reshape(Hkv, rep, ck), v)
        o_ref[0] = o_ref[0] * alpha + pv.reshape(H, D)
        m_ref[0] = m_new

        @pl.when(j == nk - 1)
        def _():
            o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)

    itemsize = jnp.dtype(dtype).itemsize
    len_in = ((Operand((B, 1), jnp.int32, (1, 1), lambda s: (s // nk, 0)),)
              if dynamic_length else ())
    if block_table is not None:
        bt_in = (Operand((B, max_blocks), jnp.int32, (1, max_blocks),
                         lambda s: (s // nk, 0)),)
        kv = (Operand((num_blocks, bs, Hkv, D), dtype,
                      (num_blocks, bs, Hkv, D), lambda s: (0, 0, 0, 0)),
              Operand((num_blocks, bs, Hkv, D), dtype,
                      (num_blocks, bs, Hkv, D), lambda s: (0, 0, 0, 0)))
        suffix, bt_name = f"_pg{bs}", ("bt",)

        def shrink(factor: int):
            sck = ck // factor
            if ck % factor or sck % bs or sck < MIN_BLOCK_ROWS:
                return None
            return decode_attention_op(B, S, H, Hkv, D, dtype=dtype, ck=sck,
                                       length=length,
                                       dynamic_length=dynamic_length,
                                       block_table=block_table)
    else:
        kv = (Operand((B, S, Hkv, D), dtype, (1, ck, Hkv, D),
                      lambda s: (s // nk, s % nk, 0, 0)),
              Operand((B, S, Hkv, D), dtype, (1, ck, Hkv, D),
                      lambda s: (s // nk, s % nk, 0, 0)))
        bt_in, suffix, bt_name, shrink = (), "", (), None
    return OpSpec(
        name=f"decode_attn_B{B}_S{S}_H{H}kv{Hkv}{suffix}",
        grid=B * nk, body=body,
        inputs=bt_in + len_in
        + (Operand((B, H, D), dtype, (1, H, D), lambda s: (s // nk, 0, 0)),)
        + kv,
        outputs=(Operand((B, H, D), jnp.float32, (1, H, D),
                         lambda s: (s // nk, 0, 0)),
                 Operand((B, H, 1), jnp.float32, (1, H, 1),
                         lambda s: (s // nk, 0, 0)),
                 Operand((B, H, 1), jnp.float32, (1, H, 1),
                         lambda s: (s // nk, 0, 0))),
        flops=2.0 * B * H * valid_len * D * 2,
        hbm_bytes=2.0 * B * valid_len * Hkv * D * itemsize
        + 2.0 * B * H * D * itemsize,
        shrink=shrink,
        tag="framework:decode_attention",
        in_names=bt_name + (("len",) if dynamic_length else ())
        + ("q", "k", "v"),
        out_names=("o", "m", "l"))
