"""Mixture-of-Experts FFN: top-k routing, capacity-based sort dispatch,
grouped expert matmul, shared experts.

The grouped expert matmul IS horizontal fusion (DESIGN.md §4.2): N
independent expert FFNs — each individually a small, low-utilization matmul —
are fused into one batched kernel (einsum "ecd,edf"), the paper's technique
applied at tensor granularity.  On TPU the hot path is the Pallas grouped
kernel in repro/kernels/moe_gmm.py; this module is the jnp substrate and the
dispatch/combine logic shared by both.

Sharding strategy (resolved by rules, DESIGN.md §7):
  * experts over 'model'  (Phi-3.5: 16/16=1 per shard)  — tokens replicated
    over model, each shard computes its experts, outputs psum-combined by
    the SPMD partitioner via the sharding constraints below.
  * experts over 'data' + expert-ffn over 'model' (DeepSeek-V2: the 222B
    expert corpus is FSDP-sharded) — the partitioner inserts the token
    all-to-all; the shard_map a2a variant lives in
    repro/distributed/moe_parallel.py and is the §Perf optimized path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.base import ParamSpec


class RouteResult(NamedTuple):
    dispatch_idx: jax.Array    # (E, C) int32 token ids (or T = drop marker)
    combine_w: jax.Array       # (E, C) fp32 routing weights (0 for dropped)
    aux_loss: jax.Array        # scalar load-balancing loss


def spec(cfg) -> dict:
    m = cfg.moe
    d, E, f = cfg.d_model, m.num_experts, m.d_ff_expert
    gated = cfg.activation in ("silu", "gelu")
    fin = 2 * f if gated else f
    out = {
        "router": ParamSpec((d, E), ("embed", None), dtype="float32"),
        "w_in": ParamSpec((E, d, fin), ("expert", "embed", "expert_ffn")),
        "w_out": ParamSpec((E, f, d), ("expert", "expert_ffn", "embed"), "out_proj"),
    }
    if m.num_shared_experts:
        fs = m.d_ff_shared
        out["shared_w_in"] = ParamSpec((d, 2 * fs if gated else fs), ("embed", "ffn"))
        out["shared_w_out"] = ParamSpec((fs, d), ("ffn", "embed"), "out_proj")
    return out


def capacity(cfg, n_tokens: int, block: int = 8) -> int:
    """Per-expert capacity for ``n_tokens`` routed tokens, aligned up to
    ``block`` (the grouped-GMM token-block granularity).  ``int()``
    truncates the fractional estimate to 0 for small batches (B=1 decode:
    1 * top_k / E * cf < 1) — floor at 1 token *before* aligning so a
    single decoding slot always has somewhere to dispatch."""
    m = cfg.moe
    c = int(n_tokens * m.top_k / m.num_experts * m.capacity_factor)
    c = max(1, c)                          # truncation floor (B=1 decode)
    return -(-c // block) * block          # align to the GMM block


def route_from_logits(cfg, logits) -> RouteResult:
    """Top-k routing with sort-based capacity dispatch, from precomputed
    router logits (T, E) fp32 — the serve executor plans the router matmul
    as a kernel and feeds its output here through a binding slot.

    Returns (E, C) dispatch indices into [0, T] where T means "empty
    slot", plus combine weights and the Switch aux loss.
    """
    m = cfg.moe
    T = logits.shape[0]
    E, K = m.num_experts, m.top_k
    C = capacity(cfg, T)

    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)               # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch): E * mean(frac_tokens * frac_prob)
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = E * jnp.sum(me * ce)

    # sort (token,slot) pairs by expert; position within expert group
    e_flat = top_e.reshape(-1)                           # (T*K,)
    w_flat = top_p.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(e_flat, stable=True)
    e_s, w_s, t_s = e_flat[order], w_flat[order], t_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[e_s]
    keep = pos_in_e < C

    dispatch = jnp.full((E, C), T, jnp.int32)            # T = empty marker
    dispatch = dispatch.at[e_s, jnp.where(keep, pos_in_e, 0)].set(
        jnp.where(keep, t_s, T), mode="drop")
    combine = jnp.zeros((E, C), jnp.float32)
    combine = combine.at[e_s, jnp.where(keep, pos_in_e, 0)].set(
        jnp.where(keep, w_s, 0.0), mode="drop")
    return RouteResult(dispatch, combine, aux)


def route(cfg, router_w, x2d) -> RouteResult:
    """Top-k routing from raw activations: x2d (T, d) @ router_w, then the
    sort-based capacity dispatch of ``route_from_logits``."""
    return route_from_logits(cfg, x2d.astype(jnp.float32) @ router_w)


def expert_ffn(cfg, p, xe):
    """Grouped expert matmul.  xe: (..., E, C, d) -> (..., E, C, d).
    This einsum is the horizontally-fused form of E independent FFNs.

    §Perf iteration 3: h is constrained with its f dim REPLICATED — the
    partitioner then all-gathers the (MB-scale) expert weights per layer
    instead of the (GB-scale) capacity activations.  Measured on
    DeepSeek-V2 train_4k: per-chip collective bytes 66GB -> ~2GB per MoE
    layer (EXPERIMENTS.md §Perf)."""
    gated = cfg.activation in ("silu", "gelu")
    h = jnp.einsum("...ecd,edf->...ecf", xe, p["w_in"])
    if gated:
        gate, up = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(gate) if cfg.activation == "silu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(h)
    ax = ("batch", "expert", "capacity", None)[-h.ndim:]
    h = shard(h, ax)
    return jnp.einsum("...ecf,efd->...ecd", h, p["w_out"])


def apply(cfg, p, x):
    """x: (B, S, d) -> (out (B,S,d), aux_loss).

    *Grouped* dispatch: tokens are split into G groups aligned with the
    (pod×)data shards of the ambient mesh; routing, gather and combine are
    group-local (never cross a shard boundary), so the only cross-device
    traffic is the (G-sharded tokens -> E-sharded experts) movement of the
    capacity buffers themselves — the all-to-all / psum the partitioner
    inserts between the constrained layouts below.  Without grouping, the
    token gather x[dispatch] all-gathers the full activation tensor.
    """
    from repro.distributed.sharding import data_shards
    B, S, d = x.shape
    T = B * S
    G = data_shards()
    if T % G or (T // G) < 8:
        G = 1
    x2d = x.reshape(G, T // G, d)
    x2d = shard(x2d, ("batch", None, "embed"))

    r = jax.vmap(lambda xs: route(cfg, p["router"], xs))(x2d)

    # group-local gather with an explicit zero row for empty slots
    x_pad = jnp.concatenate([x2d, jnp.zeros((G, 1, d), x2d.dtype)], axis=1)
    xe = jax.vmap(lambda xp, di: xp[di])(x_pad, r.dispatch_idx)   # (G,E,Cg,d)
    # dispatch layout: each data shard's tokens, all experts
    xe = shard(xe, ("batch", None, "capacity", "embed"))

    # EP resharding: when experts live on the data axis (moe-huge), move
    # the buffers to the expert layout.  The movement is written as an
    # explicit transpose+reshape between constrained layouts so the
    # partitioner lowers it as an all-to-all over 'data' (tokens -> expert
    # owners) rather than materializing full-capacity all-gathers
    # (§Perf iteration 3).  The dispatched buffer is checkpoint-named so
    # remat does not re-run the a2a in the backward pass (§Perf iter. 4).
    from jax.ad_checkpoint import checkpoint_name
    from repro.distributed.sharding import _CTX
    rules = _CTX.rules or {}
    exp_tgt = rules.get("expert")
    expert_on_data = exp_tgt is not None and "data" in (
        (exp_tgt,) if isinstance(exp_tgt, str) else tuple(exp_tgt))
    # (iteration 4 — explicit transpose+reshape movement — was REFUTED:
    #  the sharded reshape lowered to all-gathers, net flat; see §Perf.)
    if expert_on_data:
        xe = shard(xe, (None, "expert", "capacity", "embed"))
    else:
        xe = shard(xe, ("batch", "expert", "capacity", "embed"))
    xe = checkpoint_name(xe, "moe_dispatch")
    ye = expert_ffn(cfg, p, xe)
    ye = shard(ye, ("batch", None, "capacity", "embed"))
    ye = ye * r.combine_w[..., None].astype(ye.dtype)

    out = jax.vmap(lambda di, yi: jnp.zeros((T // G + 1, d), ye.dtype)
                   .at[di].add(yi))(
        r.dispatch_idx.reshape(G, -1), ye.reshape(G, -1, d))
    out = out[:, : T // G].reshape(T, d)
    aux_loss = jnp.mean(r.aux_loss)

    if cfg.moe.num_shared_experts:
        gated = cfg.activation in ("silu", "gelu")
        xf = x.reshape(T, d)
        h = xf @ p["shared_w_in"]
        if gated:
            g, u = jnp.split(h, 2, axis=-1)
            h = (jax.nn.silu(g) if cfg.activation == "silu" else jax.nn.gelu(g)) * u
        else:
            h = jax.nn.gelu(h)
        h = shard(h, ("batch", "act_ffn"))
        out = out + h @ p["shared_w_out"]
    return out.reshape(B, S, d), aux_loss
