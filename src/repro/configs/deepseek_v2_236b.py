"""DeepSeek-V2-236B — MLA + fine-grained MoE [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2]

60 layers, d_model 5120, 128 heads, MLA (kv_lora 512, q_lora 1536,
nope 128 + rope 64 head dims, v 128), vocab 102400.
MoE: 160 routed experts (d_ff 1536) top-6 + 2 shared experts; first layer
is a dense FFN (d_ff 12288).  ~236B total / ~21B active parameters.
"""
from repro.configs.base import MLA, MLAConfig, MoEConfig, ModelConfig, register


@register("deepseek-v2-236b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        block_pattern=tuple([MLA] * 60),
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,          # per assignment table; MLA stores one latent
        head_dim=192,              # qk nope 128 + rope 64
        d_ff=1536,                 # routed-expert hidden dim (per assignment)
        vocab_size=102_400,
        activation="silu",
        norm="rmsnorm",
        moe=MoEConfig(
            num_experts=160,
            top_k=6,
            d_ff_expert=1536,
            num_shared_experts=2,
            d_ff_shared=2 * 1536,
        ),
        moe_layer_overrides={0: "dense"},
        dense_d_ff_first=12288,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
        source="[arXiv:2405.04434; hf] MLA kv_lora=512, 2 shared + 160 routed top-6",
    )
