"""Three-term roofline cost model for fusion decisions (TPU v5e).

This is the napkin-math engine behind the planner and the autotuner — the
role profiling plays in the paper's ``Main()`` (Fig. 6).  The fundamental
inequality of horizontal fusion:

    t_native(A;B) = max(tcA, tmA) + max(tcB, tmB)      (two kernels, serial)
    t_hfused(A∪B) ≈ max(tcA + tcB, tmA + tmB)          (engines overlap)

    gain = t_native − t_hfused ≥ 0, strictly > 0  iff  the bound kinds
    differ (one memory-, one compute-bound) — the paper's §IV-C finding
    (Ethash+Blake256 wins, Blake256+SHA256 loses) falls out directly.

VMEM pressure is the occupancy analogue: the fused kernel needs both ops'
blocks resident (×2 for double buffering).  Exceeding the budget forfeits
pipelining — modeled as degrading overlap from max(c,m) toward c+m — the
same cliff the paper's register-cap search navigates.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.core.op_spec import OpSpec
from repro.distributed.hlo_analysis import HBM_BW, PEAK_FLOPS, VMEM_BYTES

VMEM_BUDGET = int(VMEM_BYTES * 0.8)        # leave headroom for spills/semaphores

# Sub-roofline terms (TPU v5e).  The paper's GPU gains come partly from
# effects *below* the roofline (issue-slot stalls); the TPU analogues we
# model are (a) kernel launch/teardown (~2us — paper footnote 1: fusion
# halves it) and (b) the pipeline ramp: the first block's DMA and the last
# block's compute have nothing to overlap with (one (tc+tm)/N per kernel;
# the fused kernel pays it once).  Same-resource pairs gain only these
# small terms on TPU (and can lose via VMEM pressure) — the honest
# adaptation finding, recorded in EXPERIMENTS.md §Paper-validation.
LAUNCH_S = 2e-6


def native_time(op: OpSpec) -> float:
    """Standalone kernel wall-time model: roofline + ramp + launch."""
    ramp = (op.t_compute + op.t_memory) / max(op.grid, 1)
    return max(op.t_compute, op.t_memory) + ramp + LAUNCH_S


@dataclass(frozen=True)
class Schedule:
    """Interleave ratio: ra A-steps then rb B-steps, repeating.

    (ra, rb) generalizes the paper's thread-partition point d1: it sets how
    much of each op is in flight per super-step.  DMA-elision index maps
    (core/hfuse.py) hold each op's blocks during the other's phase.
    """
    ra: int
    rb: int

    @property
    def period(self) -> int:
        return self.ra + self.rb


@dataclass
class FusedEstimate:
    t_native: float
    t_vfused: float
    t_hfused: float
    gain_vs_native: float
    gain_vs_vfused: float
    vmem_bytes: int
    vmem_ok: bool
    overlap_eff: float

    def speedup_pct(self) -> float:
        return 100.0 * self.gain_vs_native / max(self.t_native, 1e-30)


def hfused_cost(a: OpSpec, b: OpSpec, sched: Schedule,
                vmem_budget: int = VMEM_BUDGET) -> FusedEstimate:
    """Cost of the interleaved fused kernel under a schedule."""
    tcA, tmA = a.t_compute, a.t_memory
    tcB, tmB = b.t_compute, b.t_memory
    rampA = (tcA + tmA) / max(a.grid, 1)
    rampB = (tcB + tmB) / max(b.grid, 1)
    t_native = native_time(a) + native_time(b)          # two launches
    # vertical/concatenated baseline: one kernel, phases stay serial;
    # saves one launch + the boundary ramp (paper footnote 1)
    t_vfused = max(tcA, tmA) + max(tcB, tmB) \
        + max(rampA, rampB) + LAUNCH_S

    # The interleave ratio controls how long the two ops co-execute: with
    # grids Na, Nb and ratio ra:rb, co-execution lasts until the shorter
    # op (in super-steps) is exhausted; the tail runs un-overlapped.
    import math
    ssA = math.ceil(a.grid / sched.ra)
    ssB = math.ceil(b.grid / sched.rb)
    co = min(ssA, ssB)                      # super-steps with both active
    fA = co / ssA
    fB = co / ssB
    # overlapped portion: engines add; tail: leftover of the longer op
    t_overlap = max(fA * tcA + fB * tcB, fA * tmA + fB * tmB)
    t_tail = max((1 - fA) * tcA, (1 - fA) * tmA) + \
        max((1 - fB) * tcB, (1 - fB) * tmB)

    # VMEM: both ops' blocks resident, double-buffered
    vmem = 2 * (a.vmem_bytes + b.vmem_bytes)
    vmem_ok = vmem <= vmem_budget
    ramp_fused = max(rampA, rampB)
    if vmem_ok:
        t_h = t_overlap + t_tail + ramp_fused + LAUNCH_S
        eff = 1.0
    else:
        # pipelining forfeited: DMA and compute serialize (the "occupancy
        # cliff'); interpolate by how far over budget we are
        over = min(2.0, vmem / vmem_budget)
        serial = (fA * tcA + fB * tcB) + (fA * tmA + fB * tmB)
        t_h = t_tail + t_overlap + (serial - t_overlap) * (over - 1.0) \
            + ramp_fused + LAUNCH_S
        eff = max(0.0, 2.0 - over)
    return FusedEstimate(
        t_native=t_native, t_vfused=t_vfused, t_hfused=t_h,
        gain_vs_native=t_native - t_h, gain_vs_vfused=t_vfused - t_h,
        vmem_bytes=vmem, vmem_ok=vmem_ok, overlap_eff=eff)


def fusion_profitable(a: OpSpec, b: OpSpec) -> bool:
    """The paper's scenario test: different bound kinds => profitable."""
    return a.bound != b.bound


def ratio_candidates(a: OpSpec, b: OpSpec,
                     max_ratio: int = 4096) -> list[Schedule]:
    """Candidate interleave ratios ~ the paper's d1 sweep in steps of 128.

    Includes the exact grid-proportional ratio (so wildly imbalanced grids —
    e.g. a 2048-step decode-attention stream vs a 4-step prefill matmul —
    co-execute end-to-end) plus neighbours and small fixed ratios."""
    import math
    cands = {(1, 1), (2, 1), (1, 2), (4, 1), (1, 4)}
    g = a.grid / max(b.grid, 1)
    for r in (g / 2, g, g * 2):
        if r >= 1:
            cands.add((min(max_ratio, max(1, round(r))), 1))
        else:
            cands.add((1, min(max_ratio, max(1, round(1 / max(r, 1e-9))))))
    return [Schedule(ra, rb) for ra, rb in sorted(cands)]
