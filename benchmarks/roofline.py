"""§Roofline aggregator: assemble the per-(arch × shape) roofline table from
the dry-run artifacts.

Per cell:
  * full-depth SCANNED artifact       -> memory_analysis (exact buffers),
                                         compile proof, collective kinds
  * two PREFIX-DEPTH UNROLLED artifacts (_d<k> tags)
        -> exact whole-program FLOPs / HLO-bytes / collective bytes at two
           depths; linear per-pattern-unit extrapolation to full depth
           (units are homogeneous by construction — launch/dryrun.scale_depth)

Terms (TPU v5e): tc = flops/197e12, tm = bytes/819e9, tcoll = wire/50e9.
HLO-bytes note: cost_analysis "bytes accessed" counts every HLO operand
(pre-fusion upper bound on HBM traffic); we report it AND a streaming
lower bound (params+activations+cache read/write) — the truth lies between,
and the bound-type column uses the lower bound (documented in EXPERIMENTS).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.distributed.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.models import lm

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

PATTERN_UNIT = {"recurrentgemma-2b": 3, "xlstm-1.3b": 8}


def _load(name: str):
    p = ART / f"{name}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def streaming_bytes_lower_bound(arch: str, shape) -> float:
    """Per-chip HBM-traffic lower bound: params read (+opt state r/w for
    train), KV-cache read(+write), activation stream (2 bytes/elem/layer
    boundary)."""
    cfg = get_config(arch)
    n_chips = 256
    n_params = lm.count_params(cfg, active_only=shape.kind != "train")
    n_all = lm.count_params(cfg)
    B, S, L, d = shape.global_batch, shape.seq_len, cfg.num_layers, cfg.d_model
    if shape.kind == "train":
        # fwd+bwd+remat reads params ~3x, optimizer r/w m,v fp32 + grads
        per_chip = (3 * n_all * 2 + n_all * (4 + 4) * 2 + n_all * 4) / n_chips
        per_chip += 4 * B * S * d * L * 2 / n_chips          # activations
    elif shape.kind == "prefill":
        per_chip = n_params * 2 / n_chips
        per_chip += 4 * B * S * d * L * 2 / n_chips
        per_chip += 2 * B * S * cfg.num_kv_heads * cfg.resolved_head_dim \
            * L * 2 / n_chips                                # cache write
    else:   # decode
        per_chip = n_params * 2 / n_chips                    # weights stream
        # cache read: per kind
        kv = 0
        for kind in cfg.pattern:
            if kind == "attn":
                kv += 2 * B * S * cfg.num_kv_heads * cfg.resolved_head_dim * 2
            elif kind == "local":
                kv += 2 * B * min(cfg.local_window, S) \
                    * cfg.num_kv_heads * cfg.resolved_head_dim * 2
            elif kind == "mla":
                kv += B * S * (cfg.mla.kv_lora_rank
                               + cfg.mla.qk_rope_head_dim) * 2
            elif kind == "mlstm":
                f = int(cfg.mlstm_proj_factor * cfg.d_model)
                dk = (f // 2) // cfg.num_heads
                kv += B * cfg.num_heads * dk * (f // cfg.num_heads) * 4 * 2
            elif kind in ("rglru", "slstm"):
                kv += B * (cfg.lru_width or d) * 4 * 2
        per_chip += kv / n_chips
    return per_chip


def extrapolate(arch: str, shape_name: str) -> dict | None:
    unit = PATTERN_UNIT.get(arch, 1)
    d1, d2 = (unit, 2 * unit) if unit > 1 else (2, 4)
    a1 = _load(f"{arch}__{shape_name}__single_d{d1}")
    a2 = _load(f"{arch}__{shape_name}__single_d{d2}")
    if not a1 or not a2 or a1["status"] != "OK" or a2["status"] != "OK":
        return None
    L = get_config(arch).num_layers
    u1, u2, uL = d1 / unit, d2 / unit, L / unit

    def ex(key):
        x1 = a1["roofline"][key]
        x2 = a2["roofline"][key]
        per = (x2 - x1) / (u2 - u1)
        return x1 + per * (uL - u1)

    return {"flops": ex("flops_per_chip"), "hlo_bytes": ex("bytes_per_chip"),
            "coll_bytes": ex("coll_bytes_per_chip"),
            "depths": (d1, d2)}


def build_table() -> list[dict]:
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            scanned = _load(f"{arch}__{shape_name}__single")
            row = {"arch": arch, "shape": shape_name}
            if not ok:
                row.update(status="SKIP", reason=why)
                rows.append(row)
                continue
            if not scanned or scanned.get("status") != "OK":
                row.update(status="MISSING")
                rows.append(row)
                continue
            ext = extrapolate(arch, shape_name)
            sc = scanned["roofline"]
            flops = ext["flops"] if ext else sc["flops_per_chip"]
            hlo_bytes = ext["hlo_bytes"] if ext else sc["bytes_per_chip"]
            coll = ext["coll_bytes"] if ext else sc["coll_bytes_per_chip"]
            lb = streaming_bytes_lower_bound(arch, shape)
            mf = scanned["model_flops_per_chip"]
            tc = flops / PEAK_FLOPS
            tm_lb = lb / HBM_BW
            tm_ub = hlo_bytes / HBM_BW
            tcoll = coll / ICI_BW
            terms = {"compute": tc, "memory": tm_lb, "collective": tcoll}
            dom = max(terms, key=terms.get)
            t_bound = max(terms.values())
            row.update(
                status="OK", exact=bool(ext),
                flops_per_chip=flops, hlo_bytes_per_chip=hlo_bytes,
                stream_bytes_per_chip=lb, coll_bytes_per_chip=coll,
                t_compute_s=tc, t_memory_lb_s=tm_lb, t_memory_ub_s=tm_ub,
                t_collective_s=tcoll, dominant=dom, t_bound_s=t_bound,
                model_flops_per_chip=mf,
                useful_flops_ratio=mf / max(flops, 1.0),
                roofline_fraction=(tc / t_bound if t_bound else 0.0),
                mem=scanned.get("memory", {}),
            )
            rows.append(row)
    return rows


def markdown(rows) -> str:
    out = ["| arch | shape | tc (s) | tm_lb (s) | tm_ub (s) | tcoll (s) | "
           "dominant | MFU-bound | 6ND/HLO | exact |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "OK":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"{r['status']} |  |  |  |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_lb_s']:.3e} | {r['t_memory_ub_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | {r['dominant']} | "
            f"{100 * r['roofline_fraction']:.0f}% | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{'unrolled' if r['exact'] else 'scanned'} |")
    return "\n".join(out)


def run():
    rows = build_table()
    ART.parent.mkdir(parents=True, exist_ok=True)
    (ART.parent / "roofline_table.json").write_text(
        json.dumps(rows, indent=1, default=float))
    from benchmarks.common import csv_row
    csv_row("arch", "shape", "t_compute_s", "t_memory_lb_s", "t_collective_s",
            "dominant", "roofline_fraction_pct", "useful_flops_ratio")
    for r in rows:
        if r["status"] == "OK":
            csv_row(r["arch"], r["shape"], f"{r['t_compute_s']:.3e}",
                    f"{r['t_memory_lb_s']:.3e}", f"{r['t_collective_s']:.3e}",
                    r["dominant"], round(100 * r["roofline_fraction"], 1),
                    round(r["useful_flops_ratio"], 3))
        else:
            csv_row(r["arch"], r["shape"], "-", "-", "-", r["status"], "-", "-")
    return rows


if __name__ == "__main__":
    run()
