"""Fault-tolerant checkpointing.

  * atomic       — write to ``<step>.tmp-<nonce>`` then rename; a crash never
                   leaves a half-valid checkpoint visible.
  * verified     — manifest carries per-leaf byte sizes + a digest; restore
                   validates before trusting a directory.
  * async        — ``save_async`` snapshots to host memory (device_get) and
                   writes on a worker thread: training continues while bytes
                   hit disk (the I/O leaves the step critical path).
  * elastic      — leaves are saved as full logical arrays; ``restore``
                   re-lays them out onto ANY mesh via device_put with the
                   target sharding (mesh A -> mesh B rescale works by
                   construction).  At real 1000-node scale the same manifest
                   format extends to per-shard files; the reshard path is
                   identical.
  * auto-resume  — ``latest_step``/``restore_latest`` pick the newest *valid*
                   checkpoint, skipping corrupt/partial ones.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _tree_flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save(ckpt_dir: str | Path, step: int, tree: Any,
         extra_metadata: Optional[dict] = None) -> Path:
    """Synchronous atomic save.  Returns the final directory."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:010d}"
    tmp = ckpt_dir / f".tmp-{step}-{os.getpid()}-{time.time_ns()}"
    tmp.mkdir()
    manifest = {"step": step, "leaves": {}, "metadata": extra_metadata or {}}
    for name, leaf in _tree_flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = name.replace("/", "__") + ".bin"
        # raw bytes + manifest dtype (np.save can't roundtrip bfloat16)
        (tmp / fn).write_bytes(arr.tobytes())
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "bytes": int(arr.nbytes),
        }
    blob = json.dumps(manifest, sort_keys=True).encode()
    manifest["digest"] = hashlib.sha256(blob).hexdigest()
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread (cheap host copy), write on a worker."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, extra_metadata=None):
        self.wait()                       # one in flight at a time
        snapshot = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, snapshot, extra_metadata)
                self._gc()
            except BaseException as e:    # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = sorted(valid_steps(self.ckpt_dir))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.ckpt_dir / f"step_{s:010d}", ignore_errors=True)


def _valid(d: Path) -> bool:
    mf = d / "manifest.json"
    if not mf.exists():
        return False
    try:
        manifest = json.loads(mf.read_text())
        for name, info in manifest["leaves"].items():
            f = d / info["file"]
            if not f.exists() or f.stat().st_size < info["bytes"]:
                return False
        digest = manifest.pop("digest", None)
        blob = json.dumps(manifest, sort_keys=True).encode()
        return digest == hashlib.sha256(blob).hexdigest()
    except Exception:
        return False


def valid_steps(ckpt_dir: str | Path) -> list[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and _valid(d):
            out.append(int(d.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = valid_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, step: int, target_tree: Any,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``target_tree``; optionally lay leaves
    out with ``shardings`` (a matching pytree of NamedSharding) — this is the
    elastic-rescale path: the saved mesh is irrelevant."""
    d = Path(ckpt_dir) / f"step_{step:010d}"
    manifest = json.loads((d / "manifest.json").read_text())
    import jax.numpy as jnp
    leaves = {}
    for name, info in manifest["leaves"].items():
        raw = (d / info["file"]).read_bytes()
        dt = jnp.dtype(info["dtype"])             # handles bfloat16 etc.
        leaves[name] = np.frombuffer(raw, dtype=dt).reshape(info["shape"])

    named = _tree_flatten_with_paths(target_tree)
    sh_named = (_tree_flatten_with_paths(shardings)
                if shardings is not None else None)
    treedef = jax.tree.structure(target_tree)
    out = []
    for i, (name, leaf) in enumerate(named):
        if name not in leaves:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = leaves[name]
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"{name}: shape {arr.shape} != {want}")
        if sh_named is not None:
            out.append(jax.device_put(arr, sh_named[i][1]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest["metadata"]


def restore_latest(ckpt_dir, target_tree, shardings=None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    tree, meta = restore(ckpt_dir, step, target_tree, shardings)
    return step, tree, meta
