"""Train step assembly: autodiff, microbatched gradient accumulation,
optional int8 pod-axis gradient compression, AdamW update, metrics.

The returned ``train_step`` is pure — (params, opt_state, batch, step) ->
(params, opt_state, metrics) — and is jitted/lowered by the caller with
explicit shardings (see launch/dryrun.py, launch/train.py).

Distributed-optimization notes (DESIGN.md §7):
  * grad accumulation is a ``lax.scan`` over microbatches — XLA's
    latency-hiding scheduler overlaps microbatch i's gradient all-reduce
    with microbatch i+1's backward compute;
  * with ``compression='int8_pod'`` the inter-pod reduction goes through
    repro.distributed.compression (int8 on the slow links);
  * ``zero=True`` shards optimizer moments over the data axis (ZeRO-1):
    XLA turns the gradient all-reduce into reduce-scatter + the param
    update all-gather.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.train import optimizer as opt_mod
from repro.train.optimizer import AdamWConfig, OptState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    grad_accum: int = 1
    remat: bool = True
    compression: Optional[str] = None       # None | 'int8_pod'
    zero: bool = False                      # ZeRO-1 optimizer-state sharding
    max_grad_norm: float = 1.0


def plan_update_fusion(params, *, tokens: int = 4096, max_ways: int = 3,
                       bm: int = 1024, max_tensors: int = 8,
                       measure=None, cache=None):
    """Hand the optimizer's per-tensor update OpSpecs plus the backward dW
    matmuls to ``planner.plan(max_ways>=3)`` — optimizer/backward overlap is
    *planned*, not hand-wired (ROADMAP; docs/nway_fusion.md).

    Each 2-D parameter contributes its dW matmul ``x^T @ g``
    ((d_in, tokens) x (tokens, d_out)); each parameter contributes its
    AdamW-update OpSpec, which *depends on* its own dW (an update can never
    fuse with the matmul producing its gradient, but rides another
    tensor's).  ``measure``/``cache`` flow through to the autotuner, so
    schedules are profiled once (core/timing) and reused forever
    (core/schedule_cache).  Largest ``max_tensors`` parameters only — the
    tail adds launches the multi-tensor Adam path already amortizes.
    """
    import math

    from repro.core import planner
    from repro.kernels.adam import LANES, adamw_op
    from repro.kernels.matmul import matmul_1d_op

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    flat = sorted(flat, key=lambda kv: -math.prod(kv[1].shape or (1,)))
    graph: list[planner.GraphOp] = []
    for path, leaf in flat[:max_tensors]:
        pname = "".join(c if c.isalnum() else "_"
                        for c in jax.tree_util.keystr(path)).strip("_")
        n = math.prod(leaf.shape) if leaf.shape else 1
        rows = math.ceil(n / LANES)
        bm_i = min(bm, rows)
        R = math.ceil(rows / bm_i) * bm_i
        deps: frozenset[str] = frozenset()
        if leaf.ndim == 2:
            d_in, d_out = leaf.shape
            bmm = min(256, d_in)
            if d_in % bmm == 0:
                dw = matmul_1d_op(M=d_in, K=tokens, N=d_out, dtype=leaf.dtype,
                                  bm=bmm)
                dw = dataclasses.replace(dw, name=f"dW_{pname}",
                                         tag="train:dW")
                graph.append(planner.GraphOp(dw))
                deps = frozenset({dw.name})
        upd = adamw_op(R=R, dtype=leaf.dtype, bm=bm_i, name=f"adamw_{pname}")
        graph.append(planner.GraphOp(upd, deps=deps))
    return planner.plan(graph, max_ways=max_ways, measure=measure,
                        cache=cache)


def _split_microbatches(batch: dict, n: int) -> dict:
    def r(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree.map(r, batch)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype),
                        tree), norm


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None) -> Callable:
    loss_fn = functools.partial(lm.loss_fn, cfg, remat=tcfg.remat)

    def loss_wrap(params, batch):
        return loss_fn(params, batch)

    if tcfg.compression == "int8_pod" and mesh is not None:
        from repro.distributed.compression import pod_compressed_grads
        grad_fn = pod_compressed_grads(lambda p, b: loss_wrap(p, b), mesh)
    else:
        def grad_fn(params, batch):
            (l, aux), g = jax.value_and_grad(loss_wrap, has_aux=True)(params, batch)
            return l, aux, g

    def compute_grads(params, batch):
        if tcfg.grad_accum <= 1:
            return grad_fn(params, batch)
        micro = _split_microbatches(batch, tcfg.grad_accum)

        def body(carry, mb):
            acc, lsum = carry
            l, aux, g = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, gi: a + gi.astype(jnp.float32), acc, g)
            return (acc, lsum + l), aux

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, lsum), auxs = jax.lax.scan(body, (acc0, 0.0), micro)
        g = jax.tree.map(lambda a: a / tcfg.grad_accum, acc)
        aux = jax.tree.map(lambda a: a[-1], auxs)
        return lsum / tcfg.grad_accum, aux, g

    def train_step(params, opt_state: OptState, batch, step):
        loss, aux, grads = compute_grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.max_grad_norm)
        new_params, new_opt = opt_mod.update(tcfg.optimizer, grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt_mod.schedule(tcfg.optimizer, opt_state.count + 1)}
        if isinstance(aux, dict):
            metrics.update({k: v for k, v in aux.items()})
        return new_params, new_opt, metrics

    return train_step
