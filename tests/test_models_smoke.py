"""Deliverable (f): per-architecture smoke tests — reduced config of the same
family, one forward/train step on CPU, asserting output shapes + no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.models import lm

ARCHS = list_archs()


def make_batch(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision_stub":
        batch["pixel_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio_stub":
        toks = jax.random.randint(key, (B, cfg.num_codebooks, S), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch, rng):
    cfg = get_config(arch).reduced()
    params = lm.init(cfg, rng)
    B, S = 2, 32
    batch = make_batch(cfg, rng, B, S)
    logits, aux, mask = lm.forward(cfg, params, batch, remat=False)
    if cfg.frontend == "audio_stub":
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch, rng):
    from repro.train.train_loop import TrainConfig, make_train_step
    from repro.train import optimizer as opt_mod
    cfg = get_config(arch).reduced()
    params = lm.init(cfg, rng)
    opt = opt_mod.init(params)
    step = make_train_step(cfg, TrainConfig(remat=False))
    batch = make_batch(cfg, rng)
    p2, o2, metrics = step(params, opt, batch, jnp.asarray(0))
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                    - b.astype(jnp.float32)).max()),
                         params, p2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_dims(arch):
    """The full (published) config matches the assignment table."""
    cfg = get_config(arch)
    table = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256_000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50_304),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151_655),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50_304),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49_152),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256_000),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49_155),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102_400),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32_064),
        "phi3.5-moe-rms": (32, 4096, 32, 8, 6400, 32_064),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    }
    L, d, H, kv, ff, V = table[arch]
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == H and cfg.num_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == V


def test_moe_assignments():
    ds = get_config("deepseek-v2-236b")
    assert ds.moe.num_experts == 160 and ds.moe.top_k == 6
    assert ds.moe.num_shared_experts == 2
    assert ds.mla.kv_lora_rank == 512
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert phi.moe.num_experts == 16 and phi.moe.top_k == 2


def test_long_context_applicability():
    long = SHAPES["long_500k"]
    runs = {a: shape_applicable(get_config(a), long)[0] for a in ARCHS}
    assert runs["recurrentgemma-2b"] and runs["xlstm-1.3b"]
    assert not runs["granite-3-2b"] and not runs["deepseek-v2-236b"]


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "deepseek-v2-236b",
                                  "phi3.5-moe-42b-a6.6b", "minitron-8b"])
def test_param_counts_match_published(arch):
    published = {"recurrentgemma-2b": 2.68e9, "deepseek-v2-236b": 236e9,
                 "phi3.5-moe-42b-a6.6b": 41.9e9, "minitron-8b": 8e9}
    n = lm.count_params(get_config(arch))
    assert abs(n - published[arch]) / published[arch] < 0.08
