"""Property test for the paged attention kernels: decode and prefill
attention with ``block_table=`` are BITWISE equal to their contiguous
forms when the arena holds the same logical cache content — under random
block permutations (pages scattered anywhere in the arena, any order) and
under ``shrink`` variants (smaller kv-chunks, the autotuner's search
moves).  The page gather (kernels/decode_attention.gather_pages)
reassembles exactly the contiguous kernel's ``(ck, Hkv, D)`` block, so
the math is the same fp32 op sequence — equality is exact, not approx.
Deterministic engine-level coverage lives in tests/test_serve_paged.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (see "
                           "requirements.txt); deterministic paged parity "
                           "cases live in tests/test_serve_paged.py")
from hypothesis import given, settings, strategies as st

from repro.core import hfuse
from repro.kernels.decode_attention import decode_attention_op
from repro.kernels.prefill_attention import prefill_attention_op

H, Hkv, D = 4, 2, 8
BS = 16                                    # arena block size (tokens)


def _paged_cache(key, B, S, num_blocks, seed_tables):
    """Contiguous (B, S, Hkv, D) k/v plus an arena + tables holding the
    SAME logical content with pages randomly placed: block b of slot s
    lives at arena row tables[s, b], a random permutation draw."""
    kc, vc = (jax.random.normal(k, (B, S, Hkv, D), jnp.float32)
              for k in jax.random.split(key, 2))
    nper = S // BS
    rng = np.random.default_rng(seed_tables)
    tables = rng.permutation(num_blocks)[:B * nper].reshape(B, nper)
    ka = np.zeros((num_blocks, BS, Hkv, D), np.float32)
    va = np.zeros((num_blocks, BS, Hkv, D), np.float32)
    kn, vn = np.asarray(kc), np.asarray(vc)
    for b in range(B):
        for p in range(nper):
            ka[tables[b, p]] = kn[b, p * BS:(p + 1) * BS]
            va[tables[b, p]] = vn[b, p * BS:(p + 1) * BS]
    return (kc, vc, jnp.asarray(ka), jnp.asarray(va),
            jnp.asarray(tables.astype(np.int32)))


@settings(deadline=None, max_examples=10)
@given(B=st.integers(1, 3), nck=st.sampled_from([1, 2, 4]),
       shrink=st.sampled_from([None, 2]),
       seed=st.integers(0, 2 ** 16))
def test_paged_decode_bitwise_equals_contiguous(B, nck, shrink, seed):
    S = 64
    ck = S // nck
    num_blocks = B * (S // BS) + 3         # slack: unused arena rows stay 0
    key = jax.random.PRNGKey(seed)
    kq, kkv = jax.random.split(key)
    q = jax.random.normal(kq, (B, H, D), jnp.float32)
    kc, vc, ka, va, bt = _paged_cache(kkv, B, S, num_blocks, seed)
    lens = jnp.asarray(
        np.random.default_rng(seed + 1).integers(1, S + 1, (B, 1)),
        jnp.int32)
    paged = decode_attention_op(B, S, H, Hkv, D, dtype=jnp.float32, ck=ck,
                                dynamic_length=True,
                                block_table=(num_blocks, BS))
    if shrink:
        paged = paged.shrink(shrink)
        if paged is None:                  # shrunk ck below the block size
            return
        ck //= shrink
    # bitwise equality needs the SAME kv-chunk sequence (online-softmax
    # rounding depends on ck), so the reference is built at the final ck
    base = decode_attention_op(B, S, H, Hkv, D, dtype=jnp.float32, ck=ck,
                               dynamic_length=True)
    o_ref, *_ = hfuse.run_single(base, interpret=True)(lens, q, kc, vc)
    o_pg, *_ = hfuse.run_single(paged, interpret=True)(bt, lens, q, ka, va)
    assert np.array_equal(np.asarray(o_pg), np.asarray(o_ref))


@settings(deadline=None, max_examples=10)
@given(C=st.sampled_from([8, 16]), nck=st.sampled_from([1, 2, 4]),
       shrink=st.sampled_from([None, 2]),
       seed=st.integers(0, 2 ** 16))
def test_paged_prefill_bitwise_equals_contiguous(C, nck, shrink, seed):
    S = 64
    ck = S // nck
    num_blocks = S // BS + 2
    key = jax.random.PRNGKey(seed)
    kq, kkv = jax.random.split(key)
    q = jax.random.normal(kq, (C, H, D), jnp.float32)
    kc, vc, ka, va, bt = _paged_cache(kkv, 1, S, num_blocks, seed)
    off = jnp.full((1, 1),
                   int(np.random.default_rng(seed + 1).integers(0, S - C + 1)),
                   jnp.int32)
    paged = prefill_attention_op(C, S, H, Hkv, D, dtype=jnp.float32, ck=ck,
                                 block_table=(num_blocks, BS))
    if shrink:
        paged = paged.shrink(shrink)
        if paged is None:
            return
        ck //= shrink
    base = prefill_attention_op(C, S, H, Hkv, D, dtype=jnp.float32, ck=ck)
    o_ref, *_ = hfuse.run_single(base, interpret=True)(
        off, q, kc[0], vc[0])
    o_pg, *_ = hfuse.run_single(paged, interpret=True)(off, bt, q, ka, va)
    assert np.array_equal(np.asarray(o_pg), np.asarray(o_ref))
