"""The paper's Main() search (Fig. 6), adapted:

  paper                                  here
  -------------------------------------  -----------------------------------
  d1 <- 128, 256, ... (thread partition) Schedule(ra, rb) interleave ratios
  profile F without register bound       cost under full VMEM budget
  compute r0, profile F with bound r0    cost under the computed VMEM cap
                                         (shrunk block variants if provided)
  keep the fastest (F*, r*)              keep (schedule*, variant*, cap*)

Scoring: the three-term roofline cost model by default; on real TPU hardware
pass ``measure=`` (a wall-clock callable) and the loop becomes the paper's
measurement-driven profiling verbatim.  Every candidate is recorded in the
search log (EXPERIMENTS.md shows these for the fig7 pairs).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.core import hfuse
from repro.core.cost_model import (VMEM_BUDGET, FusedEstimate, Schedule,
                                   hfused_cost, ratio_candidates)
from repro.core.op_spec import OpSpec


@dataclass
class Candidate:
    sched: Schedule
    variant: int                  # index into the (opA, opB) variant list
    vmem_cap: Optional[int]
    est: FusedEstimate
    measured_s: Optional[float] = None

    @property
    def score(self) -> float:
        return self.measured_s if self.measured_s is not None else self.est.t_hfused


@dataclass
class SearchResult:
    best: Candidate
    log: list[Candidate]
    a: OpSpec
    b: OpSpec

    def build(self, *, interpret: bool = False):
        a, b = self.a, self.b
        return hfuse.generate(a, b, self.best.sched, interpret=interpret,
                              vmem_limit=self.best.vmem_cap)

    def table(self) -> list[dict]:
        return [{
            "ra": c.sched.ra, "rb": c.sched.rb, "variant": c.variant,
            "vmem_cap": c.vmem_cap, "t_hfused_us": c.est.t_hfused * 1e6,
            "speedup_pct": c.est.speedup_pct(), "vmem_ok": c.est.vmem_ok,
            "measured_s": c.measured_s,
        } for c in self.log]


def search(variants: Sequence[tuple[OpSpec, OpSpec]] | tuple[OpSpec, OpSpec],
           *, vmem_budget: int = VMEM_BUDGET,
           measure: Optional[Callable] = None) -> SearchResult:
    """Search schedules × op variants × VMEM caps.

    ``variants``: one (opA, opB) pair or a list of pairs (e.g. alternative
    block shapes — the register-cap analogue shrinks blocks to restore
    pipelining headroom).
    """
    if isinstance(variants, tuple) and isinstance(variants[0], OpSpec):
        variants = [variants]
    log: list[Candidate] = []
    best: Optional[Candidate] = None
    for vi, (a, b) in enumerate(variants):
        for sched in ratio_candidates(a, b):
            # "no register bound": full budget
            caps = [None]
            # "with bound r0": the budget both ops would need to co-reside
            # with full double buffering (paper Fig. 6 line 13-16 analogue)
            need = 2 * (a.vmem_bytes + b.vmem_bytes)
            if need > vmem_budget:
                caps.append(vmem_budget)
            for cap in caps:
                est = hfused_cost(a, b, sched,
                                  vmem_budget=cap or vmem_budget)
                cand = Candidate(sched, vi, cap, est)
                if measure is not None:
                    fused = hfuse.generate(a, b, sched, vmem_limit=cap)
                    cand.measured_s = measure(fused, a, b)
                log.append(cand)
                if best is None or cand.score < best.score:
                    best = cand
                    best_pair = (a, b)
    return SearchResult(best=best, log=log, a=best_pair[0], b=best_pair[1])
