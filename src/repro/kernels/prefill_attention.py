"""Chunked flash-attention prefill Pallas kernel: one prompt chunk of ONE
slot attending that slot's KV cache.

The serving engine admits prompts of any length by chipping them away one
chunk per iteration (docs/serving.md §Chunked prefill): the chunk's C query
rows land in the cache *before* the launch, then this kernel runs full
causal attention of those rows against the slot's whole cache — the rows
[0, off) it prefilled on earlier iterations plus the chunk itself.  At real
scale the op is compute-bound (O(C) flops per cache byte streamed), which
makes it the paper's canonical partner for the memory-bound decode
attention that shares the launch: N of these chunks (different slots) ⊕ the
vectorized decode kernel form ONE fused bundle (ServeEngine.decode_graph).

Fusible form mirrors kernels/decode_attention.py: a 1-D grid over kv
chunks, online-softmax (m, l) carries in small fp32 *outputs* with constant
index maps (not scratch) so the op composes under core/hfuse.generate.  The
chunk's start position arrives as a (1, 1) int32 operand ("off"), so one
compiled kernel serves every chunk of every prompt.

Causal chunk masking against the existing cache: query row r (absolute
position off + r) admits cache position p iff p <= off + r.  That single
predicate covers all three row classes: the already-prefilled prefix
(p < off: always admitted), the chunk itself (causal within the chunk), and
everything beyond (garbage rows the engine has not written yet: masked).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.op_spec import MIN_BLOCK_ROWS, OpSpec, Operand
from repro.kernels.decode_attention import gather_pages

NEG_INF = -1e30


def prefill_attention_op(C: int, S: int, H: int, Hkv: int, D: int,
                         dtype=jnp.bfloat16, ck: int = 1024,
                         name: str | None = None,
                         block_table=None) -> OpSpec:
    """q: (C,H,D) one chunk of one slot; cache k,v: (S,Hkv,D); off: (1,1)
    int32 absolute start position of the chunk; out o: (C,H,D) fp32.

    Grid: S // ck kv-chunk steps.  The engine scatters the chunk's own k/v
    into rows [off, off+C) before the launch, so the kernel only ever reads
    the cache — there is no in-kernel write ordering to get wrong, and the
    same (S,Hkv,D) operand contract as decode attention lets the executor
    bind both kernels to the same cache leaves in one fused launch.

    Tuned variants rebuild through the ``shrink`` factory (smaller ``ck``,
    proportionally larger grid) rather than ``op_spec.shrink_blocks`` — the
    body closes over the kv-chunk count, so a structural block rewrite
    would silently break the online-softmax recurrence.

    ``block_table=(num_blocks, block_size)``: paged form, mirroring
    kernels/decode_attention.py — k/v are the shared arena, ``S`` is the
    slot's logical capacity, and a ``(1, max_blocks)`` int32 operand ("bt",
    this slot's table row, constant across the grid like "off") maps
    logical pages to arena blocks for the in-body gather.  The reassembled
    ``(ck, Hkv, D)`` block feeds math identical to the contiguous body, so
    both forms are bitwise-equal on equal logical cache content.
    """
    assert S % ck == 0 and H % Hkv == 0
    nk = S // ck
    rep = H // Hkv
    scale = 1.0 / math.sqrt(D)
    paged = block_table is not None
    if paged:
        num_blocks, bs = block_table
        assert ck % bs == 0 and S % bs == 0
        max_blocks = S // bs
        npc = ck // bs                       # pages per kv-chunk
    resolved = name or (f"prefill_attn_C{C}_S{S}_H{H}kv{Hkv}"
                        + (f"_pg{bs}" if paged else ""))

    def body(step, off_ref, *refs):
        if paged:
            bt_ref, refs = refs[0], refs[1:]
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref = refs
        j = step                                           # kv-chunk index

        @pl.when(j == 0)
        def _():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            o_ref[...] = jnp.zeros_like(o_ref)

        off = off_ref[0, 0]
        q = q_ref[...].astype(jnp.float32) * scale         # (C, H, D)
        if paged:
            bt = bt_ref[0]                                 # (max_blocks,)
            k = gather_pages(k_ref, bt, j * npc, npc).astype(jnp.float32)
            v = gather_pages(v_ref, bt, j * npc, npc).astype(jnp.float32)
        else:
            k = k_ref[...].astype(jnp.float32)             # (ck, Hkv, D)
            v = v_ref[...].astype(jnp.float32)
        qg = q.reshape(C, Hkv, rep, D)
        s = jnp.einsum("chrd,khd->chrk", qg, k)            # (C, Hkv, rep, ck)
        kpos = j * ck + jax.lax.broadcasted_iota(jnp.int32,
                                                 (C, Hkv, rep, ck), 3)
        qpos = off + jax.lax.broadcasted_iota(jnp.int32,
                                              (C, Hkv, rep, ck), 0)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        sr = s.reshape(C, H, ck)
        m_prev = m_ref[...]                                # (C, H, 1)
        m_new = jnp.maximum(m_prev, sr.max(-1, keepdims=True))
        p = jnp.exp(sr - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        pv = jnp.einsum("chrk,khd->chrd", p.reshape(C, Hkv, rep, ck), v)
        o_ref[...] = o_ref[...] * alpha + pv.reshape(C, H, D)
        m_ref[...] = m_new

        @pl.when(j == nk - 1)
        def _():
            o_ref[...] = o_ref[...] / jnp.maximum(l_ref[...], 1e-30)

    def shrink(factor: int):
        sck = ck // factor
        if ck % factor or sck < MIN_BLOCK_ROWS or (paged and sck % bs):
            return None
        return prefill_attention_op(C, S, H, Hkv, D, dtype=dtype,
                                    ck=sck, name=resolved,
                                    block_table=block_table)

    if paged:
        bt_in = (Operand((1, max_blocks), jnp.int32, (1, max_blocks),
                         lambda s: (0, 0)),)
        kv = (Operand((num_blocks, bs, Hkv, D), dtype,
                      (num_blocks, bs, Hkv, D), lambda s: (0, 0, 0, 0)),
              Operand((num_blocks, bs, Hkv, D), dtype,
                      (num_blocks, bs, Hkv, D), lambda s: (0, 0, 0, 0)))
        bt_name = ("bt",)
    else:
        kv = (Operand((S, Hkv, D), dtype, (ck, Hkv, D),
                      lambda s: (s, 0, 0)),
              Operand((S, Hkv, D), dtype, (ck, Hkv, D),
                      lambda s: (s, 0, 0)))
        bt_in, bt_name = (), ()

    itemsize = jnp.dtype(dtype).itemsize
    return OpSpec(
        name=resolved, grid=nk, body=body,
        inputs=(Operand((1, 1), jnp.int32, (1, 1), lambda s: (0, 0)),)
        + bt_in
        + (Operand((C, H, D), dtype, (C, H, D), lambda s: (0, 0, 0)),)
        + kv,
        outputs=(Operand((C, H, D), jnp.float32, (C, H, D),
                         lambda s: (0, 0, 0)),
                 Operand((C, H, 1), jnp.float32, (C, H, 1),
                         lambda s: (0, 0, 0)),
                 Operand((C, H, 1), jnp.float32, (C, H, 1),
                         lambda s: (0, 0, 0))),
        flops=2.0 * C * H * S * D * 2,
        hbm_bytes=2.0 * S * Hkv * D * itemsize
        + C * H * D * (itemsize + 4.0) + 4.0 * C * H * 2,
        shrink=shrink,
        tag="framework:prefill_attention",
        in_names=("off",) + bt_name + ("q", "k", "v"),
        out_names=("o", "m", "l"))
