"""Differential serving harness: the continuous-batching engine must be
token-for-token identical to the legacy wavefront engine on mixed-length
prompt sets (greedy decode, interpret mode) — including requests that
finish mid-batch (EOS and budget) and slots refilled by co-prefill — plus
slot-manager edge cases: same-step mass retirement, overlong-prompt
rejection, cache-full truncation, deterministic refill order, and the
zero-new-searches replan contract for the executed continuous programs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import autotuner
from repro.core.schedule_cache import ScheduleCache
from repro.models import lm
from repro.serve.engine import Request, ServeEngine, ServeStats


def _cfg():
    return dataclasses.replace(get_config("granite-3-2b").reduced(),
                               dtype="float32")


# Three mixed-length prompt sets: (prompt lengths, token budgets).  Budgets
# are staggered so slots retire (and refill) mid-batch, never in lock-step.
PROMPT_SETS = [
    ((6, 9, 7, 12), (3, 5, 2, 4)),
    ((8, 8, 8, 8, 8), (2, 6, 3, 3, 5)),        # same length, ragged budgets
    ((10, 5, 12, 6, 9, 7), (4, 4, 1, 6, 2, 3)),
]


def _requests(cfg, lens, budgets, eos=None, seed=11):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, L).astype(np.int32),
                    max_new_tokens=m, eos_token=eos)
            for i, (L, m) in enumerate(zip(lens, budgets))]


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    wave = ServeEngine(cfg, params, batch=2, max_len=48,
                       scheduling="wavefront")
    cont = ServeEngine(cfg, params, batch=2, max_len=48,
                       scheduling="continuous")
    return cfg, params, wave, cont


@pytest.fixture(scope="module")
def executed_engine(setup):
    cfg, params, _, _ = setup
    eng = ServeEngine(cfg, params, batch=2, max_len=48,
                      scheduling="continuous", plan_fusion=True)
    assert eng.executed, "reduced granite must support the executed decode"
    return eng


# ---------------------------------------------------------------------------
# Differential parity: continuous == wavefront, token for token
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("lens,budgets", PROMPT_SETS)
def test_continuous_matches_wavefront(setup, lens, budgets):
    cfg, _params, wave, cont = setup
    rw = _requests(cfg, lens, budgets)
    rc = _requests(cfg, lens, budgets)
    wave.run(rw)
    cont.run(rc)
    assert [r.out_tokens for r in rc] == [r.out_tokens for r in rw]
    st = cont.stats
    # the slot manager really ran continuous: retirements mid-run refilled
    # slots (admissions spread over multiple steps, not one wavefront)
    assert len(st.admissions) == len(lens)
    assert len({step for step, _rid, _s in st.admissions}) > 1
    assert st.tokens == sum(len(r.out_tokens) for r in rc)
    assert 0.0 < st.occupancy <= 1.0


@pytest.mark.parametrize("lens,budgets", PROMPT_SETS)
def test_executed_continuous_matches_wavefront(setup, executed_engine,
                                               lens, budgets):
    """The planned-and-executed continuous engine (per-slot (B,) positions
    bound into the vectorized decode-attention kernel, refills co-prefilled
    through the fused launch) matches the hand-wired wavefront oracle."""
    cfg, _params, wave, _ = setup
    rw = _requests(cfg, lens, budgets)
    rc = _requests(cfg, lens, budgets)
    wave.run(rw)
    executed_engine.run(rc)
    assert [r.out_tokens for r in rc] == [r.out_tokens for r in rw]
    st = executed_engine.stats
    assert st.mixed_steps > 0, "no refill ever rode a decode step"
    # the mixed program really fused the prefill chunk with decode attention
    assert st.fused_mixed_steps == st.mixed_steps


def test_eos_finishes_mid_batch(setup):
    """A request retiring on EOS mid-batch frees its slot for refill and
    both engines agree on every stream."""
    cfg, _params, wave, cont = setup
    lens, budgets = PROMPT_SETS[0]
    probe = _requests(cfg, lens, budgets)
    wave.run(probe)
    eos = probe[1].out_tokens[1]          # fires after 2 of its 5 tokens
    rw = _requests(cfg, lens, budgets, eos=eos)
    rc = _requests(cfg, lens, budgets, eos=eos)
    wave.run(rw)
    cont.run(rc)
    assert [r.out_tokens for r in rc] == [r.out_tokens for r in rw]
    assert any(reason == "eos" for _s, _r, reason in cont.stats.retirements)
    assert len(rc[1].out_tokens) < budgets[1]


# ---------------------------------------------------------------------------
# Slot-manager edge cases
# ---------------------------------------------------------------------------
def test_all_slots_retire_same_step(setup):
    """Budgets tuned so both slots hit their limit on the same iteration;
    the manager refills both (one per step, deterministically) and the
    streams still match the oracle."""
    cfg, _params, wave, cont = setup
    lens, budgets = (7, 7, 7, 7), (3, 2, 2, 2)   # admits at steps 0,1 ->
    rw = _requests(cfg, lens, budgets)           # both retire at step 2
    rc = _requests(cfg, lens, budgets)
    wave.run(rw)
    cont.run(rc)
    assert [r.out_tokens for r in rc] == [r.out_tokens for r in rw]
    by_step: dict[int, int] = {}
    for step, _rid, _reason in cont.stats.retirements:
        by_step[step] = by_step.get(step, 0) + 1
    assert max(by_step.values()) == cont.batch, \
        f"no step retired the whole batch: {cont.stats.retirements}"


def test_overlong_prompt_rejected(setup):
    cfg, _params, _wave, cont = setup
    bad = _requests(cfg, (cont.max_len + 1,), (2,))
    with pytest.raises(ValueError, match="exceeds max_seq_len"):
        cont.run(bad)


def test_cache_full_retires_with_truncation(setup):
    """When a slot's position reaches max_len the request is retired
    (reason max_len) instead of writing past the cache."""
    cfg, params, _wave, _cont = setup
    eng = ServeEngine(cfg, params, batch=2, max_len=12,
                      scheduling="continuous")
    reqs = _requests(cfg, (10, 4), (8, 3))
    eng.run(reqs)
    # slot 0: admitted at pos 10, 1 prompt token + 2 decodes fill the cache
    assert len(reqs[0].out_tokens) == 12 - 10 + 1
    assert any(reason == "max_len" for _s, _r, reason
               in eng.stats.retirements)
    assert len(reqs[1].out_tokens) == 3          # unaffected neighbour


def test_refill_order_deterministic(setup):
    """Identical arrival queues produce identical admission schedules
    (step, rid, slot) and identical streams across runs."""
    cfg, _params, _wave, cont = setup
    lens, budgets = PROMPT_SETS[2]
    r1 = _requests(cfg, lens, budgets)
    r2 = _requests(cfg, lens, budgets)
    cont.run(r1)
    first = list(cont.stats.admissions)
    cont.run(r2)
    assert cont.stats.admissions == first
    assert [r.out_tokens for r in r1] == [r.out_tokens for r in r2]
    # FIFO admission: rids admitted in arrival order
    assert [rid for _s, rid, _slot in first] == sorted(
        rid for _s, rid, _slot in first)


def test_delayed_arrivals_idle_then_admit(setup):
    """Requests arriving after step 0 are not admitted early; the engine
    idles until the arrival step and the streams still match the oracle."""
    cfg, _params, wave, cont = setup
    rw = _requests(cfg, (6, 9), (3, 3))
    rc = _requests(cfg, (6, 9), (3, 3))
    rc[1].arrival = 4
    wave.run(rw)
    cont.run(rc)
    assert [r.out_tokens for r in rc] == [r.out_tokens for r in rw]
    admit = {rid: step for step, rid, _slot in cont.stats.admissions}
    assert admit[1] >= 4


# ---------------------------------------------------------------------------
# Executed-path contracts
# ---------------------------------------------------------------------------
def test_continuous_replan_zero_searches(setup, tmp_path):
    """A second engine over the same schedule cache re-plans every program
    (the steady mixed graph for every refill length plus the pure-decode
    step) with ZERO new autotuner searches."""
    cfg, params, wave, _ = setup
    lens, budgets = PROMPT_SETS[0]
    cache = ScheduleCache(tmp_path / "sched.json")
    e1 = ServeEngine(cfg, params, batch=2, max_len=48,
                     scheduling="continuous", plan_fusion=True,
                     schedule_cache=cache)
    e1.run(_requests(cfg, lens, budgets))
    n = autotuner.SEARCH_COUNT
    e2 = ServeEngine(cfg, params, batch=2, max_len=48,
                     scheduling="continuous", plan_fusion=True,
                     schedule_cache=cache)
    r2 = _requests(cfg, lens, budgets)
    e2.run(r2)
    assert autotuner.SEARCH_COUNT == n, "replan re-searched a bundle"
    rw = _requests(cfg, lens, budgets)
    wave.run(rw)
    assert [r.out_tokens for r in r2] == [r.out_tokens for r in rw]


def test_stacked_layers_executed_matches_oracle():
    """A 2-layer stacked config (one ATTN run, count=2) now runs the
    executed continuous path — the per-layer program scans over the
    layer-stacked param/cache leaves — and stays token-for-token with the
    wavefront oracle (which decodes through the hand-wired lm.decode_step
    for stacked runs)."""
    cfg = dataclasses.replace(_cfg(), num_layers=2,
                              block_pattern=("attn", "attn"))
    run = lm.layer_runs(cfg)[0]
    assert run.count == 2
    params = lm.init(cfg, jax.random.PRNGKey(0))
    lens, budgets = PROMPT_SETS[0]
    probe = _requests(cfg, lens, budgets)
    wave = ServeEngine(cfg, params, batch=2, max_len=48,
                       scheduling="wavefront")
    wave.run(probe)
    eos = probe[1].out_tokens[1]          # mid-batch EOS retirement too
    rw = _requests(cfg, lens, budgets, eos=eos)
    rc = _requests(cfg, lens, budgets, eos=eos)
    wave.run(rw)
    cont = ServeEngine(cfg, params, batch=2, max_len=48,
                       scheduling="continuous", plan_fusion=True)
    assert cont.executed, "stacked config must run the executed path"
    cont.run(rc)
    assert [r.out_tokens for r in rc] == [r.out_tokens for r in rw]
    assert cont.stats.fused_mixed_steps > 0


def test_stacked_layers_gated_off_wavefront_and_paged():
    """The widened executable predicate keeps its two remaining fences:
    the wavefront executed step and the paged arena stay single-layer."""
    cfg = dataclasses.replace(_cfg(), num_layers=2,
                              block_pattern=("attn", "attn"))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    wave = ServeEngine(cfg, params, batch=2, max_len=48,
                       scheduling="wavefront", plan_fusion=True)
    assert not wave.executed
    with pytest.raises(ValueError, match="single-layer"):
        ServeEngine(cfg, params, batch=2, max_len=48,
                    scheduling="continuous", plan_fusion=True,
                    paged_kv=True)


def test_stats_schema():
    st = ServeStats(batch=4)
    d = st.describe()
    assert {"steps", "decode_steps", "mixed_steps", "fused_mixed_steps",
            "tokens", "occupancy", "mixed_fraction"} <= set(d)
    assert st.occupancy == 0.0 and st.mixed_fraction == 0.0
