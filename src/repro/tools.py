"""Operational tooling CLI.

  PYTHONPATH=src python -m repro.tools cache-inspect [--cache PATH] [--json]
  PYTHONPATH=src python -m repro.tools kv-inspect --snapshot PATH [--json]

``cache-inspect`` dumps the persistent schedule cache
(core/schedule_cache.py): one row per tuned bundle — members, mode,
schedule, predicted vs measured time and their delta — plus aggregate
stats: entry count vs the LRU bound, measured coverage, mean/max
|cm-vs-measured delta|, and *stale signatures* (entries never consulted
since they were recorded: the bundle shape they key no longer occurs in
any planned graph, so they are LRU-eviction candidates).

``kv-inspect`` reads a paged KV-pool snapshot (``launch/serve
--kv-snapshot PATH``, serve/kv_pool.py): arena occupancy (in-use vs free
vs evictable-cached blocks), the prefix-index counters (hits, tokens
reused, trie size, evictions, COW copies), and one row per batch slot
with its mapped block-table prefix.
"""
from __future__ import annotations

import argparse
import json
import sys


def _resolve_cache(path: str | None):
    from repro.core.schedule_cache import ScheduleCache, default_cache
    if path:
        return ScheduleCache(path)
    return default_cache()


def cache_inspect(args) -> int:
    cache = _resolve_cache(args.cache)
    rows = []
    for key, e in sorted(cache.entries.items()):
        if not isinstance(e, dict):
            continue
        m = cache.meta.get(key, {})
        rows.append({
            "key": key[:12],
            "members": "+".join(e.get("members", ["?"])),
            "mode": e.get("mode"),
            "sched": ":".join(str(r) for r in e.get("ratios", [])),
            "vmem_cap": e.get("vmem_cap"),
            "predicted_us": (None if e.get("predicted_s") is None
                             else round(e["predicted_s"] * 1e6, 2)),
            "measured_us": (None if e.get("measured_s") is None
                            else round(e["measured_s"] * 1e6, 2)),
            "delta_pct": (None if e.get("delta_pct") is None
                          else round(e["delta_pct"], 1)),
            "uses": m.get("uses", 0),
            "last_used": m.get("last_used", 0),
        })
    stats = cache.stats()
    stats["max_entries"] = cache.max_entries
    if args.json:
        print(json.dumps({"stats": stats, "entries": rows}, indent=1))
        return 0
    print(f"# schedule cache: {stats['path']}")
    if not rows:
        print("# (empty)")
        return 0
    cols = list(rows[0])
    widths = {c: max(len(c), *(len(str(r[c])) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r[c]).ljust(widths[c]) for c in cols))
    print(f"# {stats['entries']} entries"
          + (f" (bound {stats['max_entries']}, LRU)"
             if stats["max_entries"] else " (unbounded)")
          + f", {stats['measured']} measured, "
          f"{stats['stale_never_reused']} stale (never re-consulted)")
    if stats["mean_abs_delta_pct"] is not None:
        print(f"# cm-vs-measured |delta|: mean "
              f"{stats['mean_abs_delta_pct']:.1f}% "
              f"max {stats['max_abs_delta_pct']:.1f}%")
    return 0


def kv_inspect(args) -> int:
    with open(args.snapshot) as fh:
        snap = json.load(fh)
    if args.json:
        print(json.dumps(snap, indent=1))
        return 0
    nb, bs = snap["num_blocks"], snap["block_size"]
    slots = snap["slots"]
    usable = nb - slots
    used = snap["blocks_in_use"]
    print(f"# kv pool: {nb} blocks x {bs} tokens "
          f"({slots} sentinels, {usable} usable)")
    print(f"# occupancy: {used}/{usable} in use "
          f"({used / max(usable, 1):.0%}), {snap['free_blocks']} free, "
          f"{snap['evictable_blocks']} cached-evictable")
    print(f"# prefix index: {snap['trie_nodes']} trie nodes, "
          f"{snap['prefix_hits']} hits, "
          f"{snap['prefix_tokens_reused']} tokens reused, "
          f"{snap['evictions']} evictions, "
          f"{snap['cow_copies']} cow copies")
    rows = [{"slot": t["slot"], "owned": t["owned"],
             "tokens": t["owned"] * bs,
             "blocks": ",".join(str(b) for b in t["blocks"]) or "-"}
            for t in snap["tables"]]
    cols = list(rows[0])
    widths = {c: max(len(c), *(len(str(r[c])) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r[c]).ljust(widths[c]) for c in cols))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tools")
    sub = ap.add_subparsers(dest="cmd", required=True)
    ci = sub.add_parser("cache-inspect",
                        help="dump the persistent schedule cache")
    ci.add_argument("--cache", default=None,
                    help="cache file (default: the shared default cache — "
                         "$REPRO_SCHEDULE_CACHE with its LRU bound)")
    ci.add_argument("--json", action="store_true")
    ci.set_defaults(fn=cache_inspect)
    ki = sub.add_parser("kv-inspect",
                        help="dump a paged KV-pool snapshot "
                             "(launch/serve --kv-snapshot)")
    ki.add_argument("--snapshot", required=True,
                    help="snapshot JSON written by launch/serve "
                         "--kv-snapshot PATH")
    ki.add_argument("--json", action="store_true")
    ki.set_defaults(fn=kv_inspect)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
