"""TPU analogues of the paper's 9 benchmark kernels (Fig. 8), as fusible
OpSpecs (1-D grid + BlockSpecs + resource profile).

The paper's kernels are CUDA; a mechanical port is meaningless on TPU
(DESIGN.md §2).  What the evaluation needs is kernels with the *same
resource-profile structure*, because the paper's claim is about resource
complementarity, not about maxpool per se:

  paper kernel   profile (Fig. 8)                    TPU analogue here
  ------------   ---------------------------------   ------------------------
  Maxpool        memory-bound (95% mem stalls)       maxpool    2:1 row reduce
  Batchnorm      memory-bound reduction (52-60%)     bnstats    column Σ/Σx²
  Upsample       memory-bound 1:2 expand (78-81%)    upsample   row duplicate
  Im2Col         pure data movement (27-38%)         im2col     K-shift expand
  Hist           atomic/compute mix (1-7% mem)       hist       one-hot count
  Ethash         memory-hard (96% mem stalls)        ethash_like DAG stream+mix
  SHA256         compute-bound (0% mem)              sha_like    16 matmul rounds
  Blake256       compute-bound (1.3%)                blake_like  24 matmul rounds
  Blake2B        compute-bound (1.7%)                blake2b_like 20 matmul rounds

Each factory returns (OpSpec, make_inputs, ref_fn); the oracle lives in
repro/kernels/ref.py and tests sweep shapes/dtypes in interpret mode.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.op_spec import OpSpec, Operand
from repro.kernels import ref as ref_mod

LANES = 128


def _bytes(*arrs_shapes_dtypes):
    total = 0
    for shape, dt in arrs_shapes_dtypes:
        total += math.prod(shape) * jnp.dtype(dt).itemsize
    return total


# ---------------------------------------------------------------------------
# Memory-bound atoms
# ---------------------------------------------------------------------------
def make_maxpool(R=8192, C=512, dtype=jnp.float32, bm=256):
    assert R % bm == 0 and bm % 2 == 0

    def body(step, x_ref, o_ref):
        x = x_ref[...]
        o_ref[...] = x.reshape(x.shape[0] // 2, 2, x.shape[1]).max(axis=1)

    op = OpSpec(
        name="maxpool", grid=R // bm, body=body,
        inputs=(Operand((R, C), dtype, (bm, C), lambda s: (s, 0)),),
        outputs=(Operand((R // 2, C), dtype, (bm // 2, C), lambda s: (s, 0)),),
        flops=1.0 * R * C,                       # one max per input element
        hbm_bytes=_bytes(((R, C), dtype), ((R // 2, C), dtype)),
        tag="paper:Maxpool")
    mk = lambda key: (jax.random.normal(key, (R, C), dtype),)
    return op, mk, ref_mod.maxpool


def make_upsample(R=4096, C=512, dtype=jnp.float32, bm=256):
    assert R % bm == 0

    def body(step, x_ref, o_ref):
        x = x_ref[...]
        y = jnp.broadcast_to(x[:, None, :], (x.shape[0], 2, x.shape[1]))
        o_ref[...] = y.reshape(2 * x.shape[0], x.shape[1])

    op = OpSpec(
        name="upsample", grid=R // bm, body=body,
        inputs=(Operand((R, C), dtype, (bm, C), lambda s: (s, 0)),),
        outputs=(Operand((2 * R, C), dtype, (2 * bm, C), lambda s: (s, 0)),),
        flops=0.5 * R * C,                       # ~free; traffic dominates
        hbm_bytes=_bytes(((R, C), dtype), ((2 * R, C), dtype)),
        tag="paper:Upsample")
    mk = lambda key: (jax.random.normal(key, (R, C), dtype),)
    return op, mk, ref_mod.upsample


def make_bnstats(R=16384, C=512, dtype=jnp.float32, bm=512):
    assert R % bm == 0

    def body(step, x_ref, stats_ref):
        @pl.when(step == 0)
        def _():
            stats_ref[...] = jnp.zeros_like(stats_ref)
        x = x_ref[...].astype(jnp.float32)
        stats_ref[0, :] += x.sum(axis=0)
        stats_ref[1, :] += (x * x).sum(axis=0)

    op = OpSpec(
        name="bnstats", grid=R // bm, body=body,
        inputs=(Operand((R, C), dtype, (bm, C), lambda s: (s, 0)),),
        outputs=(Operand((2, C), jnp.float32, (2, C), lambda s: (0, 0)),),
        flops=3.0 * R * C,
        hbm_bytes=_bytes(((R, C), dtype), ((2, C), jnp.float32)),
        tag="paper:Batchnorm")
    mk = lambda key: (jax.random.normal(key, (R, C), dtype),)
    return op, mk, ref_mod.bnstats


def make_im2col(R=4096, C=512, dtype=jnp.float32, bm=256, K=4):
    assert R % bm == 0

    def body(step, x_ref, o_ref):
        x = x_ref[...]
        outs = []
        for k in range(K):
            outs.append(jnp.concatenate([x[:, k:], x[:, :k]], axis=1))
        o_ref[...] = jnp.concatenate(outs, axis=1)

    op = OpSpec(
        name="im2col", grid=R // bm, body=body,
        inputs=(Operand((R, C), dtype, (bm, C), lambda s: (s, 0)),),
        outputs=(Operand((R, K * C), dtype, (bm, K * C), lambda s: (s, 0)),),
        flops=0.5 * R * C * K,
        hbm_bytes=_bytes(((R, C), dtype), ((R, K * C), dtype)),
        tag="paper:Im2Col")
    mk = lambda key: (jax.random.normal(key, (R, C), dtype),)
    return op, mk, partial(ref_mod.im2col, K=K)


def make_ethash_like(R_dag=65536, C=LANES, dtype=jnp.float32, bm=512, seed_rows=512):
    """Memory-hard: stream a large DAG, tiny mixing matmul per block."""
    assert R_dag % bm == 0 and seed_rows % bm == 0 or True

    def body(step, dag_ref, x_ref, w_ref, o_ref):
        @pl.when(step == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)
        mix = (x_ref[...] + dag_ref[...]).astype(jnp.float32)
        o_ref[...] += jnp.tanh(mix @ w_ref[...].astype(jnp.float32)
                               ).astype(o_ref.dtype)

    op = OpSpec(
        name="ethash_like", grid=R_dag // bm, body=body,
        inputs=(Operand((R_dag, C), dtype, (bm, C), lambda s: (s, 0)),
                Operand((bm, C), dtype, (bm, C), lambda s: (0, 0)),
                Operand((C, C), jnp.float32, (C, C), lambda s: (0, 0))),
        outputs=(Operand((bm, C), jnp.float32, (bm, C), lambda s: (0, 0)),),
        flops=2.0 * R_dag * C * C + 3.0 * R_dag * C,
        hbm_bytes=_bytes(((R_dag, C), dtype)) + _bytes(((bm, C), dtype)) * 2,
        tag="paper:Ethash")

    def mk(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return (jax.random.normal(k1, (R_dag, C), dtype) * 0.1,
                jax.random.normal(k2, (bm, C), dtype) * 0.1,
                jax.random.normal(k3, (C, C), jnp.float32) / math.sqrt(C))
    return op, mk, ref_mod.ethash_like


def make_hist(R=2048, C=256, dtype=jnp.float32, bm=64, bins=LANES):
    assert R % bm == 0

    def body(step, x_ref, o_ref):
        @pl.when(step == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)
        x = x_ref[...].astype(jnp.float32)
        b = jnp.clip(((x + 4.0) * (bins / 8.0)), 0, bins - 1).astype(jnp.int32)
        flat = b.reshape(-1, 1)
        eq = (flat == jax.lax.broadcasted_iota(jnp.int32, (1, bins), 1))
        o_ref[...] += eq.astype(jnp.float32).sum(axis=0, keepdims=True)

    op = OpSpec(
        name="hist", grid=R // bm, body=body,
        inputs=(Operand((R, C), dtype, (bm, C), lambda s: (s, 0)),),
        outputs=(Operand((1, bins), jnp.float32, (1, bins), lambda s: (0, 0)),),
        flops=2.0 * R * C * bins,        # compare+add per (elem, bin)
        hbm_bytes=_bytes(((R, C), dtype), ((1, bins), jnp.float32)),
        tag="paper:Hist")
    mk = lambda key: (jax.random.normal(key, (R, C), dtype),)
    return op, mk, partial(ref_mod.hist, bins=bins)


# ---------------------------------------------------------------------------
# Compute-bound atoms (hash-kernel analogues: iterated mixing matmuls)
# ---------------------------------------------------------------------------
def _make_hash_like(name: str, rounds: int, R=4096, C=LANES,
                    dtype=jnp.float32, bm=512):
    assert R % bm == 0

    def body(step, x_ref, w_ref, o_ref):
        s = x_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32)
        for _ in range(rounds):
            s = jnp.tanh(s @ w)
        o_ref[...] = s.astype(o_ref.dtype)

    op = OpSpec(
        name=name, grid=R // bm, body=body,
        inputs=(Operand((R, C), dtype, (bm, C), lambda s: (s, 0)),
                Operand((C, C), jnp.float32, (C, C), lambda s: (0, 0))),
        outputs=(Operand((R, C), dtype, (bm, C), lambda s: (s, 0)),),
        flops=rounds * 2.0 * R * C * C + rounds * 2.0 * R * C,
        hbm_bytes=_bytes(((R, C), dtype)) * 2,
        tag=f"paper:{name}")

    def mk(key):
        k1, k2 = jax.random.split(key)
        return (jax.random.normal(k1, (R, C), dtype) * 0.1,
                jax.random.normal(k2, (C, C), jnp.float32) / math.sqrt(C))
    return op, mk, partial(ref_mod.hash_like, rounds=rounds)


def make_sha_like(**kw):
    return _make_hash_like("sha_like", rounds=16, **kw)


def make_blake_like(**kw):
    return _make_hash_like("blake_like", rounds=24, **kw)


def make_blake2b_like(**kw):
    return _make_hash_like("blake2b_like", rounds=20, **kw)


# ---------------------------------------------------------------------------
# Registry (paper benchmark sets)
# ---------------------------------------------------------------------------
DL_KERNELS = {
    "maxpool": make_maxpool,
    "bnstats": make_bnstats,
    "upsample": make_upsample,
    "im2col": make_im2col,
    "hist": make_hist,
}
CRYPTO_KERNELS = {
    "ethash_like": make_ethash_like,
    "sha_like": make_sha_like,
    "blake_like": make_blake_like,
    "blake2b_like": make_blake2b_like,
}
ALL_KERNELS = {**DL_KERNELS, **CRYPTO_KERNELS}


def paper_pairs() -> list[tuple[str, str]]:
    """The 16 benchmark pairs: C(5,2)=10 DL + C(4,2)=6 crypto."""
    dl = list(DL_KERNELS)
    cr = list(CRYPTO_KERNELS)
    pairs = [(a, b) for i, a in enumerate(dl) for b in dl[i + 1:]]
    pairs += [(a, b) for i, a in enumerate(cr) for b in cr[i + 1:]]
    return pairs


def paper_triples() -> list[tuple[str, str, str]]:
    """N-way extension of Fig. 7: 3-way bundles mixing bound kinds.

    Two memory-bound streams sharing one compute-bound partner (and the
    converse) — the co-scheduling shape the pairwise paper cannot express.
    The all-compute triple is the deliberate negative (Blake256+SHA256
    generalized): it should win ~nothing and the planner should reject it.
    """
    return [
        ("maxpool", "upsample", "sha_like"),       # 2 mem + 1 compute
        ("ethash_like", "hist", "blake_like"),     # mem + mixed + compute
        ("bnstats", "im2col", "blake2b_like"),     # 2 mem + 1 compute
        ("sha_like", "blake_like", "blake2b_like"),  # negative control
    ]


# reduced-size kwargs shared by tests and benchmark smoke/numerics checks
# (interpret mode is O(grid) slow)
SMALL_KW = dict(
    maxpool=dict(R=256, C=128, bm=64), bnstats=dict(R=256, C=128, bm=64),
    upsample=dict(R=256, C=128, bm=64), im2col=dict(R=256, C=128, bm=64),
    hist=dict(R=256, C=128, bm=32), ethash_like=dict(R_dag=512, bm=128),
    sha_like=dict(R=256, bm=64), blake_like=dict(R=256, bm=64),
    blake2b_like=dict(R=256, bm=64),
)


def make_bundle(names, small: bool = False):
    """Instantiate a named bundle: ([OpSpec], [make_inputs], [ref_fn])."""
    ops, mks, refs = [], [], []
    for n in names:
        op, mk, rf = ALL_KERNELS[n](**(SMALL_KW[n] if small else {}))
        ops.append(op)
        mks.append(mk)
        refs.append(rf)
    return ops, mks, refs
