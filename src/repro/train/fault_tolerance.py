"""Fault tolerance: straggler detection, heartbeats, restartable training.

This container is a single process; the *mechanisms* are real and unit-tested
with injected clocks/failures, and the multi-host wiring points (heartbeat
transport, reschedule RPC) are narrow interfaces a cluster agent implements.

  * StepWatchdog      — EWMA + k·σ step-time anomaly detector; flags
                        stragglers and suggests mitigation (the data pipeline
                        exposes skip_ahead(); persistent stragglers escalate
                        to the HeartbeatMonitor as suspect hosts).
  * HeartbeatMonitor  — per-host liveness with deadline; dead hosts trigger
                        an elastic-rescale decision (new mesh shape), which
                        checkpoint.restore executes by re-laying-out arrays.
  * run_with_restarts — crash-looping driver: on failure, restore the latest
                        valid checkpoint and continue; bounded retries.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class StepWatchdog:
    """Flags steps slower than mean + k·σ (EWMA estimates)."""
    k: float = 3.0
    alpha: float = 0.1                 # EWMA decay
    warmup: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    stragglers: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.n += 1
        if self.n <= self.warmup:
            # prime the estimators
            self.mean = dt if self.n == 1 else \
                self.mean + (dt - self.mean) / self.n
            self.var = self.var + (dt - self.mean) ** 2 / max(self.n, 1)
            return False
        std = math.sqrt(max(self.var, 1e-12))
        is_straggler = dt > self.mean + self.k * std
        if is_straggler:
            self.stragglers.append((step, dt))
        else:
            # only track healthy steps so stragglers don't poison the stats
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


@dataclass
class HostState:
    last_seen: float
    suspect_count: int = 0


class HeartbeatMonitor:
    """Tracks per-host heartbeats; hosts silent past `deadline_s` are dead.

    ``clock`` is injectable for tests."""

    def __init__(self, hosts: list[str], deadline_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline_s = deadline_s
        self.clock = clock
        self.hosts = {h: HostState(last_seen=clock()) for h in hosts}

    def beat(self, host: str):
        self.hosts[host].last_seen = self.clock()
        self.hosts[host].suspect_count = 0

    def mark_suspect(self, host: str):
        self.hosts[host].suspect_count += 1

    def dead_hosts(self) -> list[str]:
        now = self.clock()
        return [h for h, s in self.hosts.items()
                if now - s.last_seen > self.deadline_s or s.suspect_count >= 3]

    def plan_rescale(self, mesh_shape: tuple[int, ...]) -> Optional[tuple]:
        """Largest (data', model) mesh that excludes dead hosts — shrink the
        data axis (pure-DP dimension) first; model-axis loss forces a full
        restart on fewer pods."""
        dead = len(self.dead_hosts())
        if not dead:
            return None
        data, model = mesh_shape[-2], mesh_shape[-1]
        alive = data * model - dead
        new_data = alive // model
        if new_data < 1:
            return None
        return (*mesh_shape[:-2], new_data, model)


def run_with_restarts(make_state, train_loop, *, max_failures: int = 3,
                      on_restart: Optional[Callable] = None):
    """Crash-looping driver.

    make_state() -> state (fresh or restored inside train_loop);
    train_loop(state, failure_count) runs until completion or raises.
    """
    failures = 0
    while True:
        try:
            state = make_state()
            return train_loop(state, failures)
        except KeyboardInterrupt:
            raise
        except Exception:
            failures += 1
            if failures > max_failures:
                raise
            if on_restart is not None:
                on_restart(failures)
