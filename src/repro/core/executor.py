"""Plan → program: execute a FusionPlan on live arrays.

The missing half of the paper's pipeline.  HFuse doesn't stop at a schedule
table — it emits fused source that *replaces* the original kernel launches.
``compile_plan`` is that step for this repro: it lowers a
``planner.FusionPlan`` over a ``GraphOp`` graph into a ``Program`` — a pure,
jit-compatible ``state -> state`` function in which

  * every fused bundle runs as the single Pallas call built by
    ``FusionDecision.result.build()`` (the tuned schedule, the tuned
    block-shrink variant, the tuned VMEM cap),
  * every leftover (unfused) op runs via ``hfuse.run_single``,
  * operands are threaded through a ``binding.BindingRegistry`` — the graph
    names stay symbolic here; the registry owns the mapping onto live
    param/grad/opt-state leaves (train) or KV-cache blocks and activations
    (serve).

Ordering: bundles are contracted to super-nodes (the planner only fuses
mutually independent ops, so a bundle is internally unordered) and the
contracted DAG is topologically sorted.  A dependency cycle *between*
bundles — two bundles each containing an op that feeds the other — can no
longer be planned: ``planner._contracted_acyclic`` rejects any candidate
grouping that would contract into a cycle.  The toposort here stays the
backstop for hand-built plans, surfacing the cycle as an error instead of
silently misexecuting.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.core import hfuse
from repro.core.binding import BindingRegistry, State
from repro.core.op_spec import OpSpec
from repro.core.planner import FusionPlan, GraphOp


@dataclass
class ProgramStep:
    """One launch of the compiled program."""
    members: tuple[str, ...]
    call: Callable                      # fused bundle or single-op pallas call
    ops: tuple[OpSpec, ...]             # execution OpSpecs (tuned variant)
    fused: bool
    schedule: Optional[str] = None      # ratio label, fused steps only

    def describe(self) -> dict:
        return {"members": "+".join(self.members),
                "kind": "fused" if self.fused else "single",
                "schedule": self.schedule}


@dataclass(eq=False)                       # identity hash: jax.jit(program)
class Program:
    """Executable lowering of a FusionPlan.  ``program(state) -> state`` is
    pure and traceable — wrap it (or the step function that embeds it) in
    ``jax.jit``."""
    steps: list[ProgramStep]
    bindings: BindingRegistry
    graph: tuple[GraphOp, ...]

    def __call__(self, state: State) -> State:
        for step in self.steps:
            args = [a for op in step.ops
                    for a in self.bindings.inputs(op, state)]
            outs = step.call(*args)
            off = 0
            for op in step.ops:
                n = len(op.outputs)
                state = self.bindings.commit(op, state, outs[off:off + n])
                off += n
        return state

    def describe(self) -> list[dict]:
        return [s.describe() for s in self.steps]

    @property
    def n_fused(self) -> int:
        return sum(1 for s in self.steps if s.fused)

    @property
    def fused_members(self) -> list[tuple[str, ...]]:
        """Member names of each fused launch — the co-residency record
        (e.g. the serve engine checks a prefill chunk actually shares a
        launch with decode attention before counting a step as fused-mixed)."""
        return [s.members for s in self.steps if s.fused]


def _toposort(nodes: dict[int, set[int]], order: Sequence[int]) -> list[int]:
    """Kahn's algorithm, stable in the given node order."""
    indeg = {n: len(d) for n, d in nodes.items()}
    users: dict[int, list[int]] = {n: [] for n in nodes}
    for n, deps in nodes.items():
        for d in deps:
            users[d].append(n)
    ready = [n for n in order if indeg[n] == 0]
    out: list[int] = []
    while ready:
        n = ready.pop(0)
        out.append(n)
        for u in users[n]:
            indeg[u] -= 1
            if indeg[u] == 0:
                ready.append(u)
    if len(out) != len(nodes):
        stuck = sorted(set(nodes) - set(out))
        raise ValueError(
            f"fusion plan is not executable: dependency cycle through "
            f"bundle nodes {stuck} (two bundles feed each other)")
    return out


def compile_plan(plan: FusionPlan, graph: Optional[Sequence[GraphOp]] = None,
                 bindings: Optional[BindingRegistry] = None, *,
                 interpret: bool = False) -> Program:
    """Lower ``plan`` over ``graph`` into an executable Program.

    ``graph`` defaults to the graph the plan was built from
    (``FusionPlan.graph``, recorded by ``planner.plan``).  ``bindings``
    must cover every named operand of every graph op; pass
    ``binding.default_bindings(ops)`` for the synthesized-state form.
    """
    graph = tuple(graph if graph is not None else (plan.graph or ()))
    if not graph:
        raise ValueError("compile_plan needs the planner graph "
                         "(plan.graph is empty and none was passed)")
    by_name = {g.op.name: g for g in graph}

    # ---- contract fused bundles into super-nodes -------------------------
    node_members: list[tuple[str, ...]] = \
        [d.members for d in plan.fused] + [(s,) for s in plan.singles]
    covered = [m for ms in node_members for m in ms]
    if sorted(covered) != sorted(by_name):
        raise ValueError(
            f"plan does not cover the graph exactly: plan={sorted(covered)} "
            f"graph={sorted(by_name)}")
    node_of = {m: i for i, ms in enumerate(node_members) for m in ms}
    deps: dict[int, set[int]] = {i: set() for i in range(len(node_members))}
    for i, ms in enumerate(node_members):
        for m in ms:
            for d in by_name[m].deps:
                if d in node_of and node_of[d] != i:
                    deps[i].add(node_of[d])

    order = _toposort(deps, range(len(node_members)))

    # ---- lower each node -------------------------------------------------
    if bindings is None:
        from repro.core.binding import default_bindings
        bindings = default_bindings([g.op for g in graph])
    decisions = {d.members: d for d in plan.fused}
    steps: list[ProgramStep] = []
    for i in order:
        members = node_members[i]
        if members in decisions:
            res = decisions[members].result
            call = res.build(interpret=interpret)
            ops = res.ops                       # tuned (possibly shrunk) variant
            steps.append(ProgramStep(members, call, tuple(ops), True,
                                     res.best.sched.label()))
        else:
            op = by_name[members[0]].op
            call = hfuse.run_single(op, interpret=interpret)
            steps.append(ProgramStep(members, call, (op,), False))
        for op in steps[-1].ops:
            bindings.validate(op)
    return Program(steps=steps, bindings=bindings, graph=graph)
