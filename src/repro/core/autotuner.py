"""The paper's Main() search (Fig. 6), adapted and generalized to bundles:

  paper                                  here
  -------------------------------------  -----------------------------------
  d1 <- 128, 256, ... (thread partition) Schedule ratio vectors (r_0:..:r_N)
  profile F without register bound       score under full VMEM budget
  compute r0, profile F with bound r0    score under the computed VMEM cap
                                         (+ auto-generated shrunk-block
                                          variants; op_spec.shrink_blocks)
  keep the fastest (F*, r*)              keep (schedule*, variant*, cap*)

The search is two-stage so measurement stays affordable:

  1. the three-term roofline cost model scores the whole lattice
     (ratio_candidates x variants x caps) — microseconds of Python — and
     prunes to a ``top_k`` frontier;
  2. coordinate descent refines the winner: per coordinate, halve/double
     the ratio while it improves, bounded by ``cd_budget`` evaluations —
     fine-grained ratios the {1,2,4,grid-proportional} lattice can't
     express (3+-way bundles with wildly unbalanced grids need e.g. 3:1:5).

With ``measure=`` (a wall-clock callable from ``core/timing.make_measure``)
stage 2 runs on hardware numbers — the paper's measurement-driven profiling
verbatim — and evaluates the callable on at most ``top_k + cd_budget``
candidates, strictly fewer than the exhaustive lattice.  Every candidate is
recorded in the search log with its cost-model-vs-measured delta
(EXPERIMENTS.md shows these for the fig7 pairs).

Pass ``cache=`` (core/schedule_cache.ScheduleCache) to skip the search
entirely for bundles tuned in any previous run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from repro.core import hfuse, schedule_cache as sc
from repro.core import op_spec as op_spec_mod
from repro.core.cost_model import (MAX_RATIO, VMEM_BUDGET, FusedEstimate,
                                   Schedule, hfused_cost, ratio_candidates)
from repro.core.op_spec import OpSpec

# Full (non-cache-hit) searches since import — planner/tests assert a
# repeated plan() over an unchanged graph performs ZERO of these.
SEARCH_COUNT = 0


@dataclass
class Candidate:
    sched: Schedule
    variant: int                  # index into the bundle-variant list
    vmem_cap: Optional[int]
    est: FusedEstimate
    measured_s: Optional[float] = None

    @property
    def score(self) -> float:
        return self.measured_s if self.measured_s is not None else self.est.t_hfused

    def delta_pct(self) -> Optional[float]:
        """Cost-model-vs-measured disagreement (positive: model optimistic)."""
        if self.measured_s is None:
            return None
        return 100.0 * (self.measured_s - self.est.t_hfused) \
            / max(self.est.t_hfused, 1e-30)


@dataclass
class SearchResult:
    best: Candidate
    log: list[Candidate]
    ops: tuple[OpSpec, ...]
    lattice_size: int = 0         # exhaustive stage-1 candidate count
    n_measured: int = 0           # measure() invocations (<= top_k + cd_budget)
    cache_hit: bool = False
    cache_key: Optional[str] = None   # set whenever a cache was consulted

    def build(self, *, interpret: bool = False):
        return hfuse.generate(self.ops, self.best.sched, interpret=interpret,
                              vmem_limit=self.best.vmem_cap)

    def table(self) -> list[dict]:
        return [{
            "sched": c.sched.label(), "variant": c.variant,
            "vmem_cap": c.vmem_cap, "t_hfused_us": c.est.t_hfused * 1e6,
            "speedup_pct": c.est.speedup_pct(), "vmem_ok": c.est.vmem_ok,
            "measured_s": c.measured_s,
            "cm_vs_measured_delta_pct": c.delta_pct(),
        } for c in self.log]


def _as_variants(variants) -> list[tuple[OpSpec, ...]]:
    """One bundle (sequence of OpSpecs) or a list of bundle variants."""
    variants = list(variants)
    if variants and isinstance(variants[0], OpSpec):
        return [tuple(variants)]
    return [tuple(v) for v in variants]


def _need(ops: Sequence[OpSpec]) -> int:
    """Double-buffered co-residency requirement of a bundle."""
    return 2 * sum(op.vmem_bytes for op in ops)


def _variant_fingerprint(ops: Sequence[OpSpec]) -> list:
    """JSON-able identity of one variant's OpSpecs (names, grids, blocks) —
    stored in cache entries so a hit never resolves a tuned schedule onto
    OpSpecs it wasn't tuned for."""
    return [[o.name, o.grid,
             ["x".join(map(str, x.block_shape))
              for x in (*o.inputs, *o.outputs)]]
            for o in ops]


def _shrink_variants(ops: tuple[OpSpec, ...],
                     vmem_budget: int) -> list[tuple[OpSpec, ...]]:
    """Auto-generated halved-block bundle variants (paper's register cap).

    Per-member halving (largest working set first) plus whole-bundle
    halving/quartering until the bundle co-resides — callers no longer
    pre-build variant lists.  Bounded at N + 2 variants.
    """
    variants: list[tuple[OpSpec, ...]] = []
    seen = set()

    def fingerprint(v):
        return repr(_variant_fingerprint(v))

    def add(v):
        fp = fingerprint(v)
        if fp not in seen and fp != fingerprint(ops):
            seen.add(fp)
            variants.append(v)

    for i in sorted(range(len(ops)), key=lambda i: -ops[i].vmem_bytes):
        s = op_spec_mod.shrink_blocks(ops[i], 2)
        if s is not None:
            v = list(ops)
            v[i] = s
            add(tuple(v))
    for factor in (2, 4):
        v = tuple(op_spec_mod.shrink_blocks(op, factor) or op for op in ops)
        add(v)
        if _need(v) <= vmem_budget:
            break
    return variants


def _expand_variants(variants: list[tuple[OpSpec, ...]], vmem_budget: int,
                     auto_shrink: bool) -> list[tuple[OpSpec, ...]]:
    """Deterministic variant list (also re-run on cache hits so a cached
    ``variant`` index resolves to the same OpSpecs)."""
    if auto_shrink and len(variants) == 1 and _need(variants[0]) > vmem_budget:
        variants = variants + _shrink_variants(variants[0], vmem_budget)
    return variants


def _evaluate(ops: tuple[OpSpec, ...], sched: Schedule, vi: int,
              cap: Optional[int], vmem_budget: int,
              measure: Optional[Callable]) -> Candidate:
    est = hfused_cost(ops, sched, vmem_budget=cap or vmem_budget)
    cand = Candidate(sched, vi, cap, est)
    if measure is not None:
        fused = hfuse.generate(ops, sched, vmem_limit=cap)
        cand.measured_s = measure(fused, *ops)
    return cand


def _coordinate_descent(variants, best: Candidate, vmem_budget: int,
                        measure: Optional[Callable], budget: int,
                        log: list[Candidate],
                        known: Optional[dict] = None) -> tuple[Candidate, int]:
    """Refine the incumbent's ratio vector: per coordinate, keep halving
    (then doubling) while the score improves.  At most ``budget``
    evaluations; under ``measure`` each evaluation is one profiling run.

    ``known`` maps (variant, cap, ratios) -> already-evaluated Candidate
    (the lattice / measured frontier): revisiting one reuses its score for
    free instead of burning budget — in measured mode that means never
    re-profiling a schedule the frontier already ran on hardware."""
    known = dict(known or {})
    known[(best.variant, best.vmem_cap, best.sched.ratios)] = best
    evals = 0
    improved = True
    while improved and evals < budget:
        improved = False
        for i in range(best.sched.n_ops):
            for move in ((lambda r: r // 2), (lambda r: r * 2)):
                while True:
                    ratios = list(best.sched.ratios)
                    ratios[i] = move(ratios[i])
                    if not (1 <= ratios[i] <= MAX_RATIO):
                        break
                    key = (best.variant, best.vmem_cap, tuple(ratios))
                    cand = known.get(key)
                    if cand is None:
                        if evals >= budget:
                            break
                        cand = _evaluate(variants[best.variant],
                                         Schedule(ratios), best.variant,
                                         best.vmem_cap, vmem_budget, measure)
                        evals += 1
                        log.append(cand)
                        known[key] = cand
                    if cand.score < best.score:
                        best, improved = cand, True
                    else:
                        break
    return best, evals


def search(variants: Sequence, *, vmem_budget: int = VMEM_BUDGET,
           measure: Optional[Callable] = None, top_k: int = 3,
           cd_budget: Optional[int] = None, auto_shrink: bool = True,
           cache: Optional[sc.ScheduleCache] = None,
           mesh_tag: str = "") -> SearchResult:
    """Two-stage schedule search over schedules x bundle variants x VMEM caps.

    ``variants``: one bundle — ``(opA, opB)`` or ``(op1, .., opN)`` — or a
    list of alternative bundles.  A single over-budget bundle automatically
    grows shrunk-block variants (``auto_shrink``).

    ``measure``: optional profiling callable (core/timing.make_measure);
    invoked on at most ``top_k + cd_budget`` candidates.  ``cd_budget``
    defaults to 4 measured / 24 cost-model coordinate-descent evaluations.

    ``cache``: optional ScheduleCache — a hit returns the recorded best
    schedule without searching (SEARCH_COUNT does not move).

    ``mesh_tag``: SPMD context tag (``"<axis>:<extent>"``) for plans tuned
    per shard of a mesh — part of the cache signature, so a sharded plan
    never resolves a single-device schedule (or vice versa).
    """
    variants = _expand_variants(_as_variants(variants), vmem_budget,
                                auto_shrink)
    mode = (getattr(measure, "backend", "measured")
            if measure is not None else "costmodel")
    key = None
    if cache is not None:
        key = sc.bundle_signature(variants[0], vmem_budget=vmem_budget,
                                  mode=mode, mesh_tag=mesh_tag)
        entry = cache.get(key)
        # an entry whose tuned variant doesn't resolve to the SAME OpSpecs
        # in THIS call's variant list (the signature keys only variants[0])
        # is a miss — never silently remap a schedule onto different ops
        if (entry is not None and entry["variant"] < len(variants)
                and entry.get("variant_fp")
                == _variant_fingerprint(variants[entry["variant"]])):
            ops = variants[entry["variant"]]
            cap = entry["vmem_cap"]
            est = hfused_cost(ops, Schedule(entry["ratios"]),
                              vmem_budget=cap or vmem_budget)
            best = Candidate(Schedule(entry["ratios"]), entry["variant"],
                             cap, est, measured_s=entry.get("measured_s"))
            return SearchResult(best=best, log=[best], ops=ops,
                                lattice_size=entry.get("lattice_size", 0),
                                n_measured=0, cache_hit=True, cache_key=key)

    global SEARCH_COUNT
    SEARCH_COUNT += 1

    # ---- stage 1: exhaustive lattice under the cost model (cheap) --------
    log: list[Candidate] = []
    for vi, ops in enumerate(variants):
        caps: list[Optional[int]] = [None]
        # "with bound r0": the budget the bundle would need to co-reside
        # with full double buffering (paper Fig. 6 line 13-16 analogue)
        if _need(ops) > vmem_budget:
            caps.append(vmem_budget)
        for sched in ratio_candidates(ops):
            for cap in caps:
                log.append(_evaluate(ops, sched, vi, cap, vmem_budget, None))
    lattice_size = len(log)

    # ---- stage 2: prune + (measured) refine ------------------------------
    def _key(c):
        return (c.variant, c.vmem_cap, c.sched.ratios)

    n_measured = 0
    if measure is None:
        best = min(log, key=lambda c: c.score)
        budget = 24 if cd_budget is None else cd_budget
        best, _ = _coordinate_descent(variants, best, vmem_budget, None,
                                      budget, log,
                                      known={_key(c): c for c in log})
    else:
        frontier = sorted(log, key=lambda c: c.est.t_hfused)[:max(1, top_k)]
        for c in frontier:
            fused = hfuse.generate(variants[c.variant], c.sched,
                                   vmem_limit=c.vmem_cap)
            c.measured_s = measure(fused, *variants[c.variant])
        n_measured = len(frontier)
        best = min(frontier, key=lambda c: c.score)
        budget = 4 if cd_budget is None else cd_budget
        # known = the measured frontier only: CD must never compare (or
        # re-profile) unmeasured cost-model scores against measured ones
        best, extra = _coordinate_descent(variants, best, vmem_budget,
                                          measure, budget, log,
                                          known={_key(c): c for c in frontier})
        n_measured += extra

    result = SearchResult(best=best, log=log, ops=variants[best.variant],
                          lattice_size=lattice_size, n_measured=n_measured,
                          cache_key=key)
    if cache is not None and key is not None:
        cache.put(key, {
            "members": [op.name for op in variants[0]],
            "ratios": list(best.sched.ratios),
            "variant": best.variant,
            "variant_fp": _variant_fingerprint(variants[best.variant]),
            "vmem_cap": best.vmem_cap,
            "predicted_s": best.est.t_hfused,
            "measured_s": best.measured_s,
            "delta_pct": best.delta_pct(),
            "lattice_size": lattice_size,
            "mode": mode,
        })
    return result
