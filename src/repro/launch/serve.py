"""Serving launcher (smoke-scale by default).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --requests 12 --prompt-len 16 --max-new 8

Default scheduling is continuous batching (per-slot cache positions;
docs/serving.md): slots retire and refill independently every iteration,
so ``--stagger`` (prompt-length/budget spread) and ``--arrival-rate``
(Poisson-ish arrival trace) exercise the steady mixed prefill⊕decode
graph.  ``--scheduling wavefront`` runs the legacy lock-step engine — the
differential oracle (tests/test_serve_continuous.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import PrefillBudget, Request, ServeEngine


def build_requests(cfg, args) -> list[Request]:
    """Deterministic request trace: ``--stagger`` spreads prompt lengths
    and token budgets so retirement/refill actually triggers mid-batch;
    ``--arrival-rate`` > 0 draws Poisson-ish (exponential-gap) arrivals."""
    rng = np.random.default_rng(args.seed)
    arrivals = np.zeros(args.requests)
    if args.arrival_rate > 0:
        arrivals = np.floor(np.cumsum(
            rng.exponential(1.0 / args.arrival_rate, args.requests)))
    shared = None
    if args.shared_prefix > 0:
        # one prefix drawn ONCE, common to every request — the paged-KV
        # prefix cache serves it from shared blocks after the first prompt
        shared = rng.integers(0, cfg.vocab_size,
                              args.shared_prefix).astype(np.int32)
    reqs = []
    for i in range(args.requests):
        spread = i % max(1, args.stagger)
        plen = args.prompt_len + spread
        tail = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        prompt = tail if shared is None else np.concatenate([shared, tail])
        reqs.append(Request(
            rid=i,
            prompt=prompt,
            max_new_tokens=max(1, args.max_new - spread),
            temperature=args.temperature,
            arrival=int(arrivals[i])))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduling", choices=["continuous", "wavefront"],
                    default="continuous",
                    help="continuous = per-slot cache positions with "
                         "iteration-level refill (default); wavefront = "
                         "legacy lock-step waves")
    ap.add_argument("--stagger", type=int, default=1,
                    help="spread request i's prompt length by +(i %% N) and "
                         "its budget by -(i %% N): staggers retirements so "
                         "slots refill mid-batch")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="mean request arrivals per engine step (0 = all "
                         "requests queued at step 0); Poisson-ish trace "
                         "for the occupancy report")
    ap.add_argument("--chunk-rows", type=int, default=2048,
                    help="prefill budget: max prompt rows admitted per slot "
                         "per iteration (PrefillBudget.chunk_rows); longer "
                         "prompts are chipped away chunk-by-chunk")
    ap.add_argument("--coresident-chunks", type=int, default=2,
                    help="prefill budget: max prefill chunks (distinct "
                         "slots) co-resident in one fused decode launch")
    ap.add_argument("--prefill-policy", choices=["fifo", "srpf", "eload"],
                    default="fifo",
                    help="chunk-ordering under contention: fifo = claim "
                         "order; srpf = shortest-remaining-prefill-first; "
                         "eload = srpf + shed one coresident chunk while "
                         "the per-expert hit skew exceeds the budget's "
                         "threshold (MoE executed path; "
                         "PrefillBudget.policy)")
    ap.add_argument("--reject-overlong", action="store_true",
                    help="reject prompts longer than --chunk-rows instead "
                         "of admitting them across iterations")
    ap.add_argument("--expect-stitched", action="store_true",
                    help="fail unless the executed decode program carries "
                         ">=1 epilogue chain (core/stitch.py) inside a "
                         "fused launch — the CI hybrid-fusion smoke")
    ap.add_argument("--expect-moe-fused", action="store_true",
                    help="fail unless the executed decode program puts the "
                         "grouped expert GMM (kernels/moe_gmm) in a fused "
                         "launch with a co-resident partner — the CI MoE "
                         "serve smoke")
    ap.add_argument("--kv-block-size", type=int, default=0,
                    help="paged KV: arena block size in tokens (0 = "
                         "contiguous per-slot cache; >0 enables the "
                         "KVPool paged path, requires --plan-fusion)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="paged KV: total arena blocks including per-slot "
                         "sentinels (default: batch slots' worth + slack)")
    ap.add_argument("--kv-slot-blocks", type=int, default=None,
                    help="paged KV: table columns per slot — the logical "
                         "capacity kv_slot_blocks * kv_block_size replaces "
                         "max_len as the length ceiling")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend one shared N-token prefix to every "
                         "prompt: exercises the prefix cache (later "
                         "requests skip those chunks' prefill)")
    ap.add_argument("--expect-prefix-hits", action="store_true",
                    help="fail unless the prefix cache served >=1 request "
                         "from shared blocks (stats.prefix_hit_rate > 0) — "
                         "the CI paged-serve smoke")
    ap.add_argument("--kv-snapshot", default=None, metavar="PATH",
                    help="write the final KVPool snapshot as JSON "
                         "(inspect with: python -m repro.tools kv-inspect)")
    ap.add_argument("--mesh-shape", type=int, default=0, metavar="N",
                    help="tensor-parallel serve: run the executed decode "
                         "program under shard_map on an N-device 1-D mesh "
                         "(head-sharded QKV/FFN + KV cache; requires "
                         "--plan-fusion and N local devices — on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--shard-axis", default="model",
                    help="mesh axis name the sharded leaves partition over "
                         "(default: model)")
    ap.add_argument("--expect-sharded-parity", action="store_true",
                    help="also serve the same trace on a single device and "
                         "fail unless every token stream matches — the CI "
                         "multi-device smoke gate")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-fusion", action="store_true",
                    help="plan the decode-step fusion bundle "
                         "(RMSNorm + decode attention + router/FFN)")
    ap.add_argument("--measure", choices=["auto", "interpret", "tpu", "gpu"],
                    default=None,
                    help="pick planned schedules by measurement "
                         "(core/timing.make_measure backend)")
    args = ap.parse_args(argv)
    if args.measure and not args.plan_fusion:
        ap.error("--measure only applies to --plan-fusion schedule selection")
    if args.kv_block_size > 0 and not args.plan_fusion:
        ap.error("--kv-block-size requires --plan-fusion (paged KV runs "
                 "only on the executed continuous path)")
    if args.kv_block_size <= 0 and (
            args.kv_blocks is not None or args.kv_slot_blocks is not None
            or args.expect_prefix_hits or args.kv_snapshot):
        ap.error("--kv-blocks/--kv-slot-blocks/--expect-prefix-hits/"
                 "--kv-snapshot require --kv-block-size > 0")
    if args.mesh_shape > 1 and not args.plan_fusion:
        ap.error("--mesh-shape requires --plan-fusion (only the executed "
                 "continuous step runs under shard_map)")
    if args.expect_sharded_parity and args.mesh_shape <= 1:
        ap.error("--expect-sharded-parity requires --mesh-shape > 1")

    cfg = get_config(args.arch)
    if args.scale == "smoke":
        cfg = cfg.reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    mesh = None
    if args.mesh_shape > 1:
        from jax.sharding import Mesh
        devs = jax.devices()
        if len(devs) < args.mesh_shape:
            raise SystemExit(
                f"[sharded] FAIL: --mesh-shape {args.mesh_shape} needs that "
                f"many local devices, found {len(devs)} (on CPU set "
                "XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{args.mesh_shape})")
        mesh = Mesh(np.array(devs)[:args.mesh_shape], (args.shard_axis,))
        print(f"[sharded] {args.mesh_shape}-way tensor-parallel serve over "
              f"mesh axis {args.shard_axis!r}")
    measure = None
    schedule_cache = None
    if args.plan_fusion:
        from repro.core.schedule_cache import default_cache
        from repro.core.timing import make_measure
        measure = make_measure(args.measure) if args.measure else None
        schedule_cache = default_cache()
    budget = PrefillBudget(chunk_rows=args.chunk_rows,
                           max_coresident_chunks=args.coresident_chunks,
                           policy=args.prefill_policy)
    engine = ServeEngine(cfg, params, batch=args.batch,
                         max_len=args.prompt_len + args.shared_prefix
                         + args.stagger + args.max_new + 8,
                         plan_fusion=args.plan_fusion, measure=measure,
                         schedule_cache=schedule_cache,
                         scheduling=args.scheduling,
                         prefill_budget=budget,
                         reject_overlong=args.reject_overlong,
                         paged_kv=args.kv_block_size > 0,
                         kv_block_size=args.kv_block_size or 16,
                         kv_blocks=args.kv_blocks,
                         kv_slot_blocks=args.kv_slot_blocks,
                         mesh=mesh, shard_axis=args.shard_axis)
    if engine.fusion_plan is not None:
        print("[plan-fusion] decode-step bundles:")
        for row in engine.fusion_plan.summary():
            print(f"  {row}")
        print("[plan-fusion] decode step "
              + ("EXECUTES through the plan->program executor "
                 "(core/executor)" if engine.executed
                 else "falls back to the hand-wired path"))
    if args.expect_stitched:
        from repro.core.stitch import CHAIN_SEP
        if not engine.executed:
            raise SystemExit("[stitch] FAIL: decode step is not executed "
                             "through the program executor")
        prog = engine.build_decode_program(
            prefill_chunks=args.coresident_chunks)
        chains = sorted({m for ms in prog.fused_members for m in ms
                         if CHAIN_SEP in m})
        if not chains:
            raise SystemExit("[stitch] FAIL: no epilogue chain in any "
                             "fused launch of the decode program")
        print(f"[stitch] chains in fused launches: {', '.join(chains)}")
    if args.expect_moe_fused:
        if cfg.moe is None:
            raise SystemExit("[moe] FAIL: --expect-moe-fused on a dense "
                             f"config ({cfg.name})")
        if not engine.executed:
            raise SystemExit("[moe] FAIL: MoE decode step is not executed "
                             "through the program executor")
        prog = engine.build_decode_program(
            prefill_chunks=args.coresident_chunks)
        bundles = [sorted(ms) for ms in prog.fused_members
                   if any(m.startswith("moe_gmm") for m in ms)]
        if not bundles:
            raise SystemExit("[moe] FAIL: the grouped expert GMM is not "
                             "co-resident in any fused launch of the "
                             "decode program")
        print("[moe] expert GMM co-resident in fused launch: "
              + "; ".join("+".join(ms) for ms in bundles))
    reqs = build_requests(cfg, args)
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    if args.expect_sharded_parity:
        # same deterministic trace on one device; every stream must match
        ref_engine = ServeEngine(
            cfg, params, batch=args.batch,
            max_len=args.prompt_len + args.shared_prefix + args.stagger
            + args.max_new + 8,
            plan_fusion=args.plan_fusion, schedule_cache=schedule_cache,
            scheduling=args.scheduling, prefill_budget=budget,
            reject_overlong=args.reject_overlong)
        ref = build_requests(cfg, args)
        ref_engine.run(ref)
        bad = [r.rid for r, s in zip(ref, reqs)
               if r.out_tokens != s.out_tokens]
        if bad:
            raise SystemExit("[sharded] FAIL: sharded token streams "
                             f"diverge from single-device for rids {bad}")
        print(f"[sharded] token-for-token parity with single-device "
              f"across {len(reqs)} requests")
    if args.scheduling == "continuous":
        st = engine.stats
        print(f"[slots] {st.describe()}")
        print(f"[slots] occupancy {st.occupancy:.0%}, mixed prefill⊕decode "
              f"on {st.mixed_fraction:.0%} of decode steps "
              f"({st.fused_mixed_steps} in a fused launch)")
        print(f"[prefill] {st.prefill_chunks} chunks admitted, "
              f"{st.fused_prefill_fraction:.0%} in a fused launch; "
              f"mean admission latency "
              f"{st.mean_admission_latency:.1f} steps")
        if cfg.moe is not None and st.expert_hits:
            print(f"[moe] expert hits {st.expert_hits} "
                  f"(skew {st.expert_skew:.2f}), "
                  f"{st.load_shed_steps} load-shed steps")
        if args.kv_block_size > 0:
            print(f"[paged-kv] block_size {engine.kv_block_size}, peak "
                  f"{st.blocks_in_use} blocks in use, "
                  f"prefix_hit_rate {st.prefix_hit_rate:.0%} "
                  f"({st.prefix_hits} hits, {st.prefix_tokens_reused} "
                  f"tokens reused), {st.evictions} evictions")
    if args.kv_snapshot:
        import json
        snap = engine.kv_pool.snapshot()
        with open(args.kv_snapshot, "w") as fh:
            json.dump(snap, fh, indent=2)
        print(f"[paged-kv] pool snapshot -> {args.kv_snapshot}")
    if args.expect_prefix_hits:
        if engine.stats.prefix_hit_rate <= 0:
            raise SystemExit("[paged-kv] FAIL: no request was served from "
                             "shared prefix blocks (prefix_hit_rate == 0)")
        print(f"[paged-kv] prefix cache hit "
              f"{engine.stats.prefix_hits} request(s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
