"""AdamW, implemented directly over param pytrees (fp32 moments, bf16 params).

Two update paths:
  * ``adamw_update``       — pure-jnp pytree math (default; what the dry-run
                             lowers).
  * ``hfused`` flag        — routes the per-tensor updates through the
                             horizontally-fused Pallas Adam kernel
                             (repro/kernels/adam.py): all N independent,
                             memory-bound per-tensor update "kernels" become
                             one launch over a concatenated flat buffer —
                             the paper's fusion applied to the optimizer
                             (DESIGN.md §4.3).  TPU-only; falls back to the
                             jnp path off-TPU.

Gradient compression (int8 + error feedback) lives in
repro/distributed/compression.py and wraps the gradient *before* the update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    hfused: bool = False


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(jnp.copy, zeros),
                    count=jnp.zeros((), jnp.int32))


def abstract_init(abstract_params) -> OptState:
    zeros = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                         abstract_params)
    return OptState(m=zeros, v=zeros,
                    count=jax.ShapeDtypeStruct((), jnp.int32))


def update(ocfg: AdamWConfig, grads, state: OptState, params, *,
           program=None):
    """One AdamW step.  Returns (new_params, new_state).

    ``program`` (a ``train_loop.UpdateProgram``) routes the whole update
    through the plan->program executor — fused bundles via
    ``SearchResult.build()``, leftover tensors via ``run_single`` — instead
    of the hand-wired jnp / hfused-kernel paths below.
    """
    cnt = state.count + 1
    lr = schedule(ocfg, cnt)
    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1 - b1 ** cnt.astype(jnp.float32)
    bc2 = 1 - b2 ** cnt.astype(jnp.float32)

    if program is not None:
        # b1/b2/eps/wd are baked into the program's op bodies at build time
        # (lr/bias corrections ride in the scalars operand) — a program built
        # for different hyperparameters must never silently apply them
        built = getattr(program, "hyper", None)
        want = dict(b1=ocfg.b1, b2=ocfg.b2, eps=ocfg.eps,
                    wd=ocfg.weight_decay)
        if built is not None and built != want:
            raise ValueError(
                f"update program was built for hyperparameters {built}, "
                f"but update() was called with {want} — rebuild it with "
                f"build_update_program(params, ocfg)")
        new_params, new_m, new_v = program(params, grads, state.m, state.v,
                                           lr=lr, bc1=bc1, bc2=bc2)
        return new_params, OptState(new_m, new_v, cnt)

    if ocfg.hfused and jax.default_backend() == "tpu":
        from repro.kernels import ops as kops
        new_params, new_m, new_v = kops.hfused_adamw(
            params, grads, state.m, state.v,
            lr=lr, b1=b1, b2=b2, eps=ocfg.eps, wd=ocfg.weight_decay,
            bc1=bc1, bc2=bc2)
        return new_params, OptState(new_m, new_v, cnt)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / bc1
        vh = v2 / bc2
        step = mh / (jnp.sqrt(vh) + ocfg.eps) + ocfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, cnt)
