"""KVPool unit contract (serve/kv_pool.py): pure host-side bookkeeping —
no jax, no engine.  Covers the block lifecycle (admit -> ensure_rows ->
register -> release), refcounted prefix sharing and its alignment floors,
the cached-after-release rematch path, LRU leaf eviction under pressure,
copy-on-write guards, arena exhaustion/recovery, and the snapshot shape
``python -m repro.tools kv-inspect`` consumes."""
import pytest

from repro.serve.kv_pool import KVPool


BS = 4          # block_size: small enough to exercise multi-block prompts


def _pool(num_blocks=16, slots=2, max_blocks=8):
    return KVPool(num_blocks=num_blocks, block_size=BS, slots=slots,
                  max_blocks_per_slot=max_blocks)


def _prompt(n, base=100):
    return list(range(base, base + n))


def test_validates_capacity():
    with pytest.raises(ValueError, match="must exceed slots"):
        KVPool(num_blocks=2, block_size=BS, slots=2, max_blocks_per_slot=4)


def test_sentinel_tables_and_initial_occupancy():
    p = _pool()
    # slot b's table points wholly at sentinel b until rows are mapped
    assert p.table[0] == [0] * 8 and p.table[1] == [1] * 8
    assert p.blocks_in_use == 0 and len(p.free) == 14


def test_lifecycle_ensure_register_release():
    p = _pool()
    toks = _prompt(10)                     # 2 full blocks + 2 tail tokens
    assert p.admit(0, toks, chunk=BS, now=0) == 0   # cold: nothing to reuse
    assert p.ensure_rows(0, 0, 10, now=0)
    assert p.owned[0] == 3 and p.blocks_in_use == 3
    assert all(p.ref[b] == 1 for b in p.table[0][:3])
    p.register(0, toks, now=1)
    snap = p.snapshot()
    assert snap["trie_nodes"] == 2         # only FULL blocks are indexed
    p.release(0)
    assert p.owned[0] == 0 and p.table[0] == [0] * 8
    # 2 registered blocks stay cached, the tail block frees; only the
    # chain's LEAF is immediately evictable (children pin parents)
    assert p.blocks_in_use == 2 and snap["block_size"] == BS
    assert p.snapshot()["evictable_blocks"] == 1


def test_prefix_reuse_shares_blocks_and_bumps_refs():
    p = _pool()
    toks = _prompt(12)                     # 3 full blocks
    p.admit(0, toks, chunk=BS, now=0)
    p.ensure_rows(0, 0, 12, now=0)
    p.register(0, toks, now=0)
    shared = list(p.table[0][:3])
    reuse = p.admit(1, toks + _prompt(4, base=900), chunk=BS, now=1)
    # all 3 indexed blocks match; floor(12, lcm(4,4)) = 12 tokens skipped
    assert reuse == 12
    assert p.table[1][:3] == shared
    assert all(p.ref[b] == 2 for b in shared)
    assert p.prefix_hits == 1 and p.prefix_tokens_reused == 12
    p.release(0)
    assert all(p.ref[b] == 1 for b in shared)   # slot 1 still holds them


def test_reuse_floored_to_chunk_and_capped_below_prompt_len():
    p = _pool(num_blocks=32, max_blocks=16)
    toks = _prompt(24)                     # 6 full blocks
    p.admit(0, toks, chunk=BS, now=0)
    p.ensure_rows(0, 0, 24, now=0)
    p.register(0, toks, now=0)
    p.release(0)
    # chunk=8 -> align lcm(4,8)=8: 6 matched blocks (24 tok) floor to 24,
    # but the cap len-1=23 forces the FINAL chunk to run -> floor to 16
    assert p.admit(1, toks, chunk=8, now=1) == 16
    p.release(1)
    # ragged chunk=6 -> align lcm(4,6)=12: floor(23, 12) = 12
    assert p.admit(0, toks, chunk=6, now=2) == 12
    p.release(0)
    # longer prompt sharing the prefix: cap no longer binds, full 24 reused
    assert p.admit(1, toks + _prompt(8, base=500), chunk=8, now=3) == 24


def test_cached_blocks_rematch_after_release():
    """The whole point of the prefix cache: blocks survive their slot."""
    p = _pool()
    toks = _prompt(8)
    p.admit(0, toks, chunk=BS, now=0)
    p.ensure_rows(0, 0, 8, now=0)
    p.register(0, toks, now=0)
    blocks = list(p.table[0][:2])
    p.release(0)
    assert p.blocks_in_use == 2            # cached, not freed
    reuse = p.admit(0, toks + [7, 8, 9], chunk=BS, now=1)
    assert reuse == 8 and p.table[0][:2] == blocks


def test_lru_evicts_leaf_first_and_counts():
    p = _pool(num_blocks=2 + 4, slots=2, max_blocks=4)   # 4 usable blocks
    a = _prompt(8, base=0)                 # 2 blocks, chained in the trie
    p.admit(0, a, chunk=BS, now=0)
    p.ensure_rows(0, 0, 8, now=0)
    p.register(0, a, now=0)
    p.release(0)                           # both cached: leaf + its parent
    assert p.snapshot()["evictable_blocks"] == 1   # children pin parents
    # demand 3 fresh blocks: 2 free remain, so the LRU ref-0 LEAF evicts
    # first; its parent becomes a leaf and evicts next
    p.admit(1, _prompt(12, base=500), chunk=BS, now=5)
    assert p.ensure_rows(1, 0, 12, now=5)
    assert p.evictions == 1
    p.release(1)


def test_exhaustion_returns_false_keeps_partial_and_recovers():
    p = _pool(num_blocks=2 + 3, slots=2, max_blocks=8)   # 3 usable blocks
    p.admit(0, _prompt(12), chunk=BS, now=0)
    assert p.ensure_rows(0, 0, 12, now=0)          # takes all 3
    p.admit(1, _prompt(12, base=500), chunk=BS, now=0)
    assert not p.ensure_rows(1, 0, 12, now=0)      # arena exhausted
    assert p.owned[1] == 0                         # nothing was mappable
    p.release(0)                                   # unregistered -> freed
    assert p.ensure_rows(1, 0, 12, now=1)          # recovers
    # beyond the per-slot table is a hard False, no allocation attempted
    assert not p.ensure_rows(1, 8 * BS, 8 * BS + 1, now=1)


def test_prepare_write_cow_on_shared_and_registered_blocks():
    p = _pool()
    toks = _prompt(8)
    p.admit(0, toks, chunk=BS, now=0)
    p.ensure_rows(0, 0, 8, now=0)
    assert p.prepare_write(0, 5, now=0) is None    # private: no copy
    p.register(0, toks, now=0)
    blk = p.table[0][1]
    got = p.prepare_write(0, 5, now=1)             # registered: future
    assert got is not None and got[1] == blk       # slots may match it
    new, old = got
    assert p.table[0][1] == new and p.ref[old] == 0 and p.ref[new] == 1
    assert p.cow_copies == 1
    # unmapped row (sentinel) never copies
    assert p.prepare_write(1, 0, now=1) is None


def test_snapshot_reports_tables_and_counters():
    p = _pool()
    toks = _prompt(12)
    p.admit(0, toks, chunk=BS, now=0)
    p.ensure_rows(0, 0, 12, now=0)
    snap = p.snapshot()
    assert snap["num_blocks"] == 16 and snap["slots"] == 2
    assert snap["blocks_in_use"] == 3
    row = snap["tables"][0]
    assert row["owned"] == 3 and len(row["blocks"]) == 3
    assert snap["tables"][1]["blocks"] == []
    for key in ("free_blocks", "evictable_blocks", "evictions",
                "prefix_hits", "prefix_tokens_reused", "cow_copies",
                "trie_nodes"):
        assert key in snap
