"""Flash attention Pallas kernel (train/prefill): 3-D grid
(batch·head, q-block, kv-block) with online-softmax VMEM carries.

Block sizes are MXU-aligned (multiples of 128 on the contracting dims).
The causal mask is applied per (q-block, kv-block) tile; fully-masked tiles
still stream (structural simplicity over triangle skipping — the cost model
accounts the 2x; see EXPERIMENTS.md §Perf hillclimb #3 for the skip variant).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, nk: int, bq: int, bk: int, causal: bool, scale: float):
    j = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale           # (bq, D)
    k = k_ref[0].astype(jnp.float32)                   # (bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    if causal:
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, interpret: bool = False):
    """q,k,v: (BH, S, D) — batch and heads pre-flattened (GQA callers repeat
    or reshape KV before the call; see ops.flash_attention)."""
    BH, S, D = q.shape
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    from jax.experimental.pallas import tpu as pltpu
    scratch = [pltpu.VMEM((bq, 1), jnp.float32),
               pltpu.VMEM((bq, 1), jnp.float32),
               pltpu.VMEM((bq, D), jnp.float32)]
    return pl.pallas_call(
        functools.partial(_flash_kernel, nk=nk, bq=bq, bk=bk, causal=causal,
                          scale=1.0 / math.sqrt(D)),
        grid=(BH, nq, nk),
        in_specs=[pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0))],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
