"""Paged KV-cache pool: block arena + per-slot tables + prefix cache.

`KVPool` is the host-side allocator behind the paged serve path
(docs/serving.md §Paged KV).  Instead of one contiguous ``(B, max_len)``
cache region per engine, k/v live in a flat ``(num_blocks, block_size,
Hkv, D)`` arena (device arrays owned by the ENGINE's state pytree — the
pool only does the bookkeeping) and each batch slot holds a
``(max_blocks,)`` int32 table mapping its logical pages to arena blocks.
The paged attention kernels (kernels/decode_attention.py,
kernels/prefill_attention.py with ``block_table=``) gather pages by table
lookup inside the fused launch, so "where slot b's cache lives" becomes
data, not layout — and ``max_len`` stops being a per-engine constant.

Three mechanisms ride on the table indirection:

* **Refcounting + copy-on-write.**  A block may back several slots (shared
  prompt prefix).  Writers call :meth:`prepare_write` first; a block with
  ``ref > 1`` (or one registered in the prefix index, which future slots
  may still match) is replaced by a fresh private copy for that slot and
  the engine copies the arena row.  On the engine path writes only ever
  land on private blocks (admission floors prefix reuse to whole chunks),
  so COW is a guarded invariant rather than a hot path.

* **Prefix cache.**  A radix trie keyed on *full blocks of prompt tokens*
  (node = ``block_size`` consecutive token ids).  :meth:`admit` walks the
  trie along the new prompt; matched nodes' blocks are shared into the
  slot's table (ref++) and those tokens' prefill chunks are SKIPPED
  entirely.  :meth:`register` extends the trie with the slot's own blocks
  once its prompt is fully prefilled, making them matchable by later
  requests.

* **LRU eviction.**  Released blocks that the trie still references stay
  cached (ref 0, evictable) instead of returning to the free list.  When
  :meth:`_alloc` finds the free list empty it evicts the least-recently-
  used ref-0 trie LEAF (children pin parents, so the trie never dangles);
  admission degrades gracefully instead of rejecting.

Blocks ``0..slots-1`` are per-slot *sentinels*: slot ``b``'s table rows
point at sentinel ``b`` until a real block is mapped, so the vectorized
decode scatter (which writes through ``table[b, pos[b] // bs]`` for every
slot, active or not) can never land an inactive slot's stale write on a
block another slot owns.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from math import gcd
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class _TrieNode:
    """One full block of prompt tokens in the prefix index."""
    key: Tuple[int, ...]                      # block_size token ids
    block: int                                # arena block holding their k/v
    parent: Optional["_TrieNode"]
    children: Dict[Tuple[int, ...], "_TrieNode"] = field(default_factory=dict)
    last_use: int = 0


class KVPool:
    """Bookkeeping for a paged KV arena shared by ``slots`` batch slots.

    Pure host-side Python (no jax): the engine reads :attr:`table` into a
    device array each step and performs the actual arena row copies /
    scatters itself.  ``now`` arguments are the engine's monotonic step
    counter, used for LRU ordering.
    """

    def __init__(self, num_blocks: int, block_size: int, slots: int,
                 max_blocks_per_slot: int):
        if num_blocks < slots + 1:
            raise ValueError(
                f"num_blocks={num_blocks} must exceed slots={slots} "
                "(one sentinel per slot + at least one usable block)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.slots = slots
        self.max_blocks_per_slot = max_blocks_per_slot
        # blocks 0..slots-1 are sentinels, never allocated or shared
        self.free: deque[int] = deque(range(slots, num_blocks))
        self.ref: List[int] = [0] * num_blocks
        self.table: List[List[int]] = [
            [b] * max_blocks_per_slot for b in range(slots)]
        self.owned: List[int] = [0] * slots   # mapped real blocks per slot
        self._root = _TrieNode(key=(), block=-1, parent=None)
        self._node_of: Dict[int, _TrieNode] = {}   # arena block -> trie node
        self.evictions = 0
        self.prefix_hits = 0
        self.prefix_tokens_reused = 0
        self.cow_copies = 0

    # ------------------------------------------------------------------
    # Allocation / eviction
    # ------------------------------------------------------------------
    @property
    def blocks_in_use(self) -> int:
        """Real (non-sentinel) blocks not on the free list — includes ref-0
        blocks parked in the prefix cache."""
        return self.num_blocks - self.slots - len(self.free)

    def _alloc(self, now: int) -> Optional[int]:
        if self.free:
            return self.free.popleft()
        victim = self._lru_evictable()
        if victim is None:
            return None
        self._evict(victim)
        return self.free.popleft()

    def _lru_evictable(self) -> Optional[_TrieNode]:
        best = None
        for node in self._node_of.values():
            if node.children or self.ref[node.block] != 0:
                continue                       # interior or still shared
            if best is None or node.last_use < best.last_use:
                best = node
        return best

    def _evict(self, node: _TrieNode) -> None:
        assert not node.children and self.ref[node.block] == 0
        node.parent.children.pop(node.key, None)
        del self._node_of[node.block]
        self.free.append(node.block)
        self.evictions += 1

    # ------------------------------------------------------------------
    # Slot lifecycle
    # ------------------------------------------------------------------
    def admit(self, slot: int, tokens: Sequence[int], chunk: int,
              now: int) -> int:
        """Claim ``slot`` for a prompt.  Walks the prefix trie along
        ``tokens`` (full-block granularity), shares every matched block
        into the slot's table, and returns ``reuse``: the number of prompt
        tokens whose prefill is skipped.  ``reuse`` is floored to a
        multiple of ``chunk`` (the engine's effective chunk rows) so every
        later chunk offset stays chunk-aligned, and capped at
        ``len(tokens) - 1`` so the final chunk — the one whose last row
        yields the first sampled token — always runs."""
        bs = self.block_size
        row = self.table[slot]
        assert self.owned[slot] == 0, f"slot {slot} not released"
        matched: List[int] = []
        node = self._root
        for i in range(min(len(tokens) // bs, self.max_blocks_per_slot)):
            key = tuple(tokens[i * bs:(i + 1) * bs])
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = now
            matched.append(child.block)
            node = child
        # floor to a multiple of both the chunk (keeps every later chunk
        # offset aligned) and the block size (shares only whole blocks)
        chunk = max(chunk, 1)
        align = bs * chunk // gcd(bs, chunk)
        reuse = min(len(matched) * bs, len(tokens) - 1)
        reuse -= reuse % align
        nblk = reuse // bs
        for i in range(nblk):
            self.ref[matched[i]] += 1
            row[i] = matched[i]
        self.owned[slot] = nblk
        if reuse:
            self.prefix_hits += 1
            self.prefix_tokens_reused += reuse
        return reuse

    def ensure_rows(self, slot: int, start: int, end: int,
                    now: int) -> bool:
        """Map fresh private blocks for logical token rows [start, end).
        Returns False (partial mappings kept) when the arena is exhausted
        even after eviction — the engine stalls that chunk / retires that
        slot instead of crashing."""
        bs = self.block_size
        row = self.table[slot]
        first = start // bs
        last = (max(end, start + 1) - 1) // bs
        if last >= self.max_blocks_per_slot:
            return False
        for i in range(first, last + 1):
            if i < self.owned[slot]:
                continue                       # already mapped (or shared)
            blk = self._alloc(now)
            if blk is None:
                return False
            self.ref[blk] += 1
            row[i] = blk
            self.owned[slot] = i + 1
        return True

    def prepare_write(self, slot: int, logical: int,
                      now: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write guard before the engine writes token row
        ``logical`` of ``slot``.  If the backing block is shared
        (``ref > 1``) or registered in the prefix index, map a fresh
        private block and return ``(new, old)`` so the engine copies the
        arena row; returns None when the block is already private."""
        i = logical // self.block_size
        row = self.table[slot]
        blk = row[i]
        if blk < self.slots:
            return None                        # sentinel: nothing mapped yet
        if self.ref[blk] == 1 and blk not in self._node_of:
            return None
        new = self._alloc(now)
        if new is None:
            raise RuntimeError("KVPool exhausted during copy-on-write")
        self.ref[blk] -= 1
        self.ref[new] += 1
        row[i] = new
        self.cow_copies += 1
        return new, blk

    def register(self, slot: int, tokens: Sequence[int], now: int) -> None:
        """Extend the prefix trie with ``slot``'s blocks for every FULL
        block of ``tokens`` (called once the prompt is entirely in cache).
        Blocks already indexed (shared via a prefix hit) are skipped; a
        block can back at most one trie node."""
        bs = self.block_size
        row = self.table[slot]
        node = self._root
        for i in range(min(len(tokens) // bs, self.owned[slot])):
            key = tuple(tokens[i * bs:(i + 1) * bs])
            child = node.children.get(key)
            if child is None:
                blk = row[i]
                if blk in self._node_of:
                    break                      # block already indexes another path
                child = _TrieNode(key=key, block=blk, parent=node,
                                  last_use=now)
                node.children[key] = child
                self._node_of[blk] = child
            child.last_use = now
            node = child

    def release(self, slot: int) -> None:
        """Retire ``slot``: down-ref every mapped block and reset the table
        row to the slot's sentinel.  Ref-0 blocks return to the free list
        unless the prefix trie still indexes them — those stay cached
        (evictable) so the next matching prompt skips their prefill."""
        row = self.table[slot]
        for i in range(self.owned[slot]):
            blk = row[i]
            self.ref[blk] -= 1
            if self.ref[blk] == 0 and blk not in self._node_of:
                self.free.append(blk)
            row[i] = slot
        self.owned[slot] = 0

    # ------------------------------------------------------------------
    # Introspection (python -m repro.tools kv-inspect)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        cached = sum(1 for b, n in self._node_of.items()
                     if self.ref[b] == 0 and not n.children)
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "slots": self.slots,
            "max_blocks_per_slot": self.max_blocks_per_slot,
            "blocks_in_use": self.blocks_in_use,
            "free_blocks": len(self.free),
            "evictable_blocks": cached,
            "evictions": self.evictions,
            "prefix_hits": self.prefix_hits,
            "prefix_tokens_reused": self.prefix_tokens_reused,
            "cow_copies": self.cow_copies,
            "trie_nodes": len(self._node_of),
            "tables": [
                {"slot": b, "owned": self.owned[b],
                 "blocks": list(self.table[b][:self.owned[b]])}
                for b in range(self.slots)],
        }
