"""Minitron-8B — pruned Nemotron-4 [arXiv:2407.14679; hf:nvidia/Minitron-8B-Base]

32 layers, d_model 4096, 32 heads (GQA kv=8), d_ff 16384, vocab 256000,
squared-ReLU MLP (non-gated), LayerNorm, RoPE.
"""
from repro.configs.base import ModelConfig, register


@register("minitron-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=256_000,
        activation="relu2_mlp",
        norm="layernorm",
        source="[arXiv:2407.14679; hf] pruned nemotron",
    )
