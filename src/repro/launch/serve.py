"""Serving launcher (smoke-scale by default).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --requests 12 --prompt-len 16 --max-new 8

Default scheduling is continuous batching (per-slot cache positions;
docs/serving.md): slots retire and refill independently every iteration,
so ``--stagger`` (prompt-length/budget spread) and ``--arrival-rate``
(Poisson-ish arrival trace) exercise the steady mixed prefill⊕decode
graph.  ``--scheduling wavefront`` runs the legacy lock-step engine — the
differential oracle (tests/test_serve_continuous.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import PrefillBudget, Request, ServeEngine


def build_requests(cfg, args) -> list[Request]:
    """Deterministic request trace: ``--stagger`` spreads prompt lengths
    and token budgets so retirement/refill actually triggers mid-batch;
    ``--arrival-rate`` > 0 draws Poisson-ish (exponential-gap) arrivals."""
    rng = np.random.default_rng(args.seed)
    arrivals = np.zeros(args.requests)
    if args.arrival_rate > 0:
        arrivals = np.floor(np.cumsum(
            rng.exponential(1.0 / args.arrival_rate, args.requests)))
    reqs = []
    for i in range(args.requests):
        spread = i % max(1, args.stagger)
        plen = args.prompt_len + spread
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=max(1, args.max_new - spread),
            temperature=args.temperature,
            arrival=int(arrivals[i])))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduling", choices=["continuous", "wavefront"],
                    default="continuous",
                    help="continuous = per-slot cache positions with "
                         "iteration-level refill (default); wavefront = "
                         "legacy lock-step waves")
    ap.add_argument("--stagger", type=int, default=1,
                    help="spread request i's prompt length by +(i %% N) and "
                         "its budget by -(i %% N): staggers retirements so "
                         "slots refill mid-batch")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="mean request arrivals per engine step (0 = all "
                         "requests queued at step 0); Poisson-ish trace "
                         "for the occupancy report")
    ap.add_argument("--chunk-rows", type=int, default=2048,
                    help="prefill budget: max prompt rows admitted per slot "
                         "per iteration (PrefillBudget.chunk_rows); longer "
                         "prompts are chipped away chunk-by-chunk")
    ap.add_argument("--coresident-chunks", type=int, default=2,
                    help="prefill budget: max prefill chunks (distinct "
                         "slots) co-resident in one fused decode launch")
    ap.add_argument("--prefill-policy", choices=["fifo", "srpf"],
                    default="fifo",
                    help="chunk-ordering under contention: fifo = claim "
                         "order; srpf = shortest-remaining-prefill-first "
                         "(PrefillBudget.policy)")
    ap.add_argument("--reject-overlong", action="store_true",
                    help="reject prompts longer than --chunk-rows instead "
                         "of admitting them across iterations")
    ap.add_argument("--expect-stitched", action="store_true",
                    help="fail unless the executed decode program carries "
                         ">=1 epilogue chain (core/stitch.py) inside a "
                         "fused launch — the CI hybrid-fusion smoke")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-fusion", action="store_true",
                    help="plan the decode-step fusion bundle "
                         "(RMSNorm + decode attention + router/FFN)")
    ap.add_argument("--measure", choices=["auto", "interpret", "tpu", "gpu"],
                    default=None,
                    help="pick planned schedules by measurement "
                         "(core/timing.make_measure backend)")
    args = ap.parse_args(argv)
    if args.measure and not args.plan_fusion:
        ap.error("--measure only applies to --plan-fusion schedule selection")

    cfg = get_config(args.arch)
    if args.scale == "smoke":
        cfg = cfg.reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    measure = None
    schedule_cache = None
    if args.plan_fusion:
        from repro.core.schedule_cache import default_cache
        from repro.core.timing import make_measure
        measure = make_measure(args.measure) if args.measure else None
        schedule_cache = default_cache()
    budget = PrefillBudget(chunk_rows=args.chunk_rows,
                           max_coresident_chunks=args.coresident_chunks,
                           policy=args.prefill_policy)
    engine = ServeEngine(cfg, params, batch=args.batch,
                         max_len=args.prompt_len + args.stagger
                         + args.max_new + 8,
                         plan_fusion=args.plan_fusion, measure=measure,
                         schedule_cache=schedule_cache,
                         scheduling=args.scheduling,
                         prefill_budget=budget,
                         reject_overlong=args.reject_overlong)
    if engine.fusion_plan is not None:
        print("[plan-fusion] decode-step bundles:")
        for row in engine.fusion_plan.summary():
            print(f"  {row}")
        print("[plan-fusion] decode step "
              + ("EXECUTES through the plan->program executor "
                 "(core/executor)" if engine.executed
                 else "falls back to the hand-wired path"))
    if args.expect_stitched:
        from repro.core.stitch import CHAIN_SEP
        if not engine.executed:
            raise SystemExit("[stitch] FAIL: decode step is not executed "
                             "through the program executor")
        prog = engine.build_decode_program(
            prefill_chunks=args.coresident_chunks)
        chains = sorted({m for ms in prog.fused_members for m in ms
                         if CHAIN_SEP in m})
        if not chains:
            raise SystemExit("[stitch] FAIL: no epilogue chain in any "
                             "fused launch of the decode program")
        print(f"[stitch] chains in fused launches: {', '.join(chains)}")
    reqs = build_requests(cfg, args)
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    if args.scheduling == "continuous":
        st = engine.stats
        print(f"[slots] {st.describe()}")
        print(f"[slots] occupancy {st.occupancy:.0%}, mixed prefill⊕decode "
              f"on {st.mixed_fraction:.0%} of decode steps "
              f"({st.fused_mixed_steps} in a fused launch)")
        print(f"[prefill] {st.prefill_chunks} chunks admitted, "
              f"{st.fused_prefill_fraction:.0%} in a fused launch; "
              f"mean admission latency "
              f"{st.mean_admission_latency:.1f} steps")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
