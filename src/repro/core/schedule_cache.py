"""Persistent schedule cache — never re-search a bundle we already tuned.

Production serving/training plans the same op graphs every process start;
the paper's Main() search (and especially its measured form) is pure waste
the second time.  Entries are keyed by an exact *bundle signature* — op
names, grids, operand shapes/dtypes/block shapes, FLOP/byte counts, the
VMEM budget, and the scoring mode (cost model vs measurement backend) — so
any change that could alter the tuned schedule changes the key and the
stale entry is simply never consulted again.  Bumping ``CACHE_VERSION``
(schema or search-semantics changes) invalidates every file on disk.

File format (JSON, human-inspectable):

    {"version": 2,
     "entries": {"<sha256-prefix>": {
        "members": ["maxpool", "upsample", "sha_like"],
        "ratios": [2, 1, 4], "variant": 0, "vmem_cap": null,
        "predicted_s": 1.2e-4, "measured_s": 1.3e-4, "delta_pct": 8.3,
        "mode": "costmodel"}}}

``autotuner.search(cache=...)`` and ``planner.plan(cache=...)`` consult it;
``default_cache()`` resolves the shared on-disk location
(``$REPRO_SCHEDULE_CACHE`` or ``~/.cache/repro/schedule_cache.json``).
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
from pathlib import Path
from typing import Optional, Sequence

import jax.numpy as jnp

from repro.core.op_spec import OpSpec

CACHE_VERSION = 2

_DEFAULT: Optional["ScheduleCache"] = None


def bundle_signature(ops: Sequence[OpSpec], *, vmem_budget: int,
                     mode: str = "costmodel") -> str:
    """Exact identity of a tuning problem.  Includes everything the search
    outcome can depend on; excludes anything it cannot (body closures)."""
    parts = [f"v{CACHE_VERSION}", mode, str(int(vmem_budget))]
    for op in ops:
        operands = ",".join(
            "{}:{}:{}".format("x".join(map(str, o.shape)),
                              jnp.dtype(o.dtype).name,
                              "x".join(map(str, o.block_shape)))
            for o in (*op.inputs, *op.outputs))
        parts.append(f"{op.name}|g{op.grid}|f{op.flops:.6g}"
                     f"|h{op.hbm_bytes:.6g}|{operands}")
    return hashlib.sha256(";".join(parts).encode()).hexdigest()[:32]


class ScheduleCache:
    """In-memory dict with optional JSON persistence and hit/miss stats."""

    def __init__(self, path: Optional[os.PathLike | str] = None):
        self.path = Path(path) if path else None
        self.entries: dict[str, dict] = {}
        self.hits = 0
        self.misses = 0
        self._defer = False
        self._dirty = False
        if self.path is not None:
            self.load()

    # ------------------------------------------------------------------
    def get(self, key: str) -> Optional[dict]:
        entry = self.entries.get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def put(self, key: str, entry: dict) -> None:
        self.entries[key] = entry
        if self._defer:
            self._dirty = True
        elif self.path is not None:
            self.save()

    @contextlib.contextmanager
    def batched(self):
        """Defer disk writes until the block exits — one save for a whole
        plan()/search() burst instead of a full-file rewrite per put()."""
        prev = self._defer
        self._defer = True
        try:
            yield self
        finally:
            self._defer = prev
            if self._dirty and not self._defer:
                self._dirty = False
                self.save()

    def __len__(self) -> int:
        return len(self.entries)

    # ------------------------------------------------------------------
    def load(self) -> None:
        if self.path is None or not self.path.exists():
            return
        try:
            blob = json.loads(self.path.read_text())
        except (json.JSONDecodeError, OSError):
            return                            # corrupt cache == empty cache
        if blob.get("version") != CACHE_VERSION:
            return                            # stale schema: discard
        self.entries.update(blob.get("entries", {}))

    def save(self) -> None:
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        # merge concurrent writers: keys are content-addressed, so entries
        # another process added since our load are kept (ours win on clash)
        merged = dict(self.entries)
        try:
            blob = json.loads(self.path.read_text())
            if blob.get("version") == CACHE_VERSION:
                merged = {**blob.get("entries", {}), **self.entries}
        except (FileNotFoundError, json.JSONDecodeError, OSError):
            pass
        tmp = self.path.with_suffix(f".tmp.{os.getpid()}")   # no writer races
        tmp.write_text(json.dumps(
            {"version": CACHE_VERSION, "entries": merged},
            indent=1, sort_keys=True))
        tmp.replace(self.path)                # atomic on POSIX
        self.entries = merged


def default_cache() -> ScheduleCache:
    """Process-wide cache at $REPRO_SCHEDULE_CACHE (or ~/.cache/repro/)."""
    global _DEFAULT
    if _DEFAULT is None:
        path = os.environ.get(
            "REPRO_SCHEDULE_CACHE",
            str(Path.home() / ".cache" / "repro" / "schedule_cache.json"))
        _DEFAULT = ScheduleCache(path)
    return _DEFAULT
