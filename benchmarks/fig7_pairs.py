"""Paper Fig. 7: kernel-pair speedup vs native, across execution-time ratios
— plus the beyond-paper N-way sweep (pair-vs-triple bundles).

16 pairs (10 DL + 6 crypto).  For each pair we sweep the workload of the
first kernel to hit execution-time ratios ~{1/4, 1/2, 1, 2, 4} and report:
  VFuse  — concatenated-grid kernel (no interleave; saves launch only)
  Naive  — horizontal fusion, even 1:1 interleave, no tuning
  HFuse  — autotuned schedule (+VMEM cap when needed) — the paper's system

``run_nway`` extends the sweep to the registered 3-way bundles
(paper_triples): for each triple it reports the best *pairwise* plan (best
fused pair + the leftover single — all the paper's system can do) against
the 3-way bundle, so the perf trajectory captures pair-vs-triple speedups.

Numerics of every reported fused kernel are asserted against the oracles
at reduced sizes.
"""
from __future__ import annotations

import itertools
import math

import jax

from benchmarks.common import check_bundle_numerics, check_pair_numerics, csv_row
from repro.core import autotuner
from repro.core.cost_model import Schedule, hfused_cost, native_time
from repro.kernels import paper_suite as ps

RATIOS = (0.25, 0.5, 1.0, 2.0, 4.0)

# reduced-size kwargs for the numerics check (interpret mode is O(grid) slow)
SMALL = ps.SMALL_KW


def scaled(name: str, scale: float):
    """Scale a kernel's row-count to scale its native time."""
    f = ps.ALL_KERNELS[name]
    base_R = {"ethash_like": 65536}.get(name, None)
    if name == "ethash_like":
        R = max(1024, int(base_R * scale) // 512 * 512)
        return f(R_dag=R)
    op0, _, _ = f()
    R0 = op0.inputs[0].shape[0]
    bm = op0.inputs[0].block_shape[0]
    R = max(bm, int(R0 * scale) // bm * bm)
    return f(R=R)


def run(check_numerics: bool = True):
    csv_row("pair", "ratio", "t_native_us", "vfuse_speedup_pct",
            "naive_speedup_pct", "hfuse_speedup_pct", "hfuse_sched",
            "vmem_cap", "max_err")
    for a_name, b_name in ps.paper_pairs():
        for ratio in RATIOS:
            opB, mkB, refB = ps.ALL_KERNELS[b_name]()
            opA0, _, _ = ps.ALL_KERNELS[a_name]()
            # scale A so t_native(A) = ratio * t_native(B)
            scale = ratio * opB.t_native / max(opA0.t_native, 1e-30)
            opA, mkA, refA = scaled(a_name, scale)

            t_native = native_time(opA) + native_time(opB)
            naive = hfused_cost(opA, opB, Schedule(1, 1))
            res = autotuner.search((opA, opB))
            best = res.best
            err = float("nan")
            if check_numerics and ratio == 1.0:
                sA, mA, rA = ps.ALL_KERNELS[a_name](**SMALL[a_name])
                sB, mB, rB = ps.ALL_KERNELS[b_name](**SMALL[b_name])
                err = check_pair_numerics(sA, mA, rA, sB, mB, rB, best.sched)
                assert err < 2e-2, (a_name, b_name, err)
            csv_row(f"{a_name}+{b_name}", ratio,
                    round(t_native * 1e6, 2),
                    round(100 * (t_native - naive.t_vfused) / t_native, 1),
                    round(naive.speedup_pct(), 1),
                    round(best.est.speedup_pct(), 1),
                    f"{best.sched.ra}:{best.sched.rb}",
                    best.vmem_cap or 0,
                    f"{err:.1e}")


def run_nway(check_numerics: bool = True):
    """Pair-vs-triple: best pairwise plan vs the N-way bundle per triple."""
    csv_row("bundle", "t_native_us", "best_pair_speedup_pct",
            "nway_speedup_pct", "nway_sched", "vmem_cap", "max_err")
    for names in ps.paper_triples():
        ops, _, _ = ps.make_bundle(names)
        t_native = sum(native_time(op) for op in ops)

        # best the pairwise system can do: fuse one pair, run the rest native
        best_pair_t = t_native
        for i, j in itertools.combinations(range(len(ops)), 2):
            res = autotuner.search((ops[i], ops[j]))
            rest = sum(native_time(ops[k]) for k in range(len(ops))
                       if k not in (i, j))
            best_pair_t = min(best_pair_t, res.best.est.t_hfused + rest)

        res_n = autotuner.search(tuple(ops))
        err = float("nan")
        if check_numerics:
            # verify the TUNED schedule (ratio vectors are size-independent),
            # not just 1:1:..:1 — the row's speedup belongs to this kernel
            small_ops, mks, refs = ps.make_bundle(names, small=True)
            err = check_bundle_numerics(small_ops, mks, refs,
                                        res_n.best.sched)
            assert err < 2e-2, (names, err)
        csv_row("+".join(names),
                round(t_native * 1e6, 2),
                round(100 * (t_native - best_pair_t) / t_native, 1),
                round(res_n.best.est.speedup_pct(), 1),
                res_n.best.sched.label(),
                res_n.best.vmem_cap or 0,
                f"{err:.1e}")


if __name__ == "__main__":
    run()
    run_nway()
