"""ShapeDtypeStruct stand-ins for every model input, per (arch × shape) —
weak-type-correct, shardable, zero allocation.  Frontends are stubs per the
assignment: the VLM supplies precomputed patch embeddings, the audio arch
supplies EnCodec codebook token ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.frontend == "audio_stub":
        return {"tokens": jax.ShapeDtypeStruct((B, cfg.num_codebooks, S), i32),
                "labels": jax.ShapeDtypeStruct((B, cfg.num_codebooks, S), i32)}
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
             "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.frontend == "vision_stub":
        batch["pixel_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = train_batch_specs(cfg, shape)
    b.pop("labels")
    return b


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    B = shape.global_batch
    if cfg.frontend == "audio_stub":
        return jax.ShapeDtypeStruct((B, cfg.num_codebooks), jnp.int32)
    return jax.ShapeDtypeStruct((B,), jnp.int32)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract KV/recurrent cache for a decode step at context length S."""
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(lambda: lm.init_cache(cfg, B, S))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Everything the step function for this shape consumes (sans params)."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    if shape.kind == "decode":
        return {"cache": cache_specs(cfg, shape),
                "tokens": decode_token_specs(cfg, shape)}
    raise ValueError(shape.kind)
