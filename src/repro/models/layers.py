"""Shared transformer layers — pure JAX, functional, roofline-honest.

Attention is implemented *blockwise* (flash-style running-max/sum over KV
chunks) so that the lowered HLO streams O(S·d) bytes instead of
materializing S×S score matrices: the dry-run roofline reads bytes from the
compiled HLO, so the jnp reference path must have the same asymptotic memory
behaviour as the Pallas TPU kernels in repro/kernels/.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard
from repro.runtime_flags import maybe_scan
from repro.models.base import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_spec(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), "zeros", dtype="float32")}


def rmsnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(x.dtype)


def layernorm_spec(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), "ones", dtype="float32"),
            "bias": ParamSpec((d,), ("embed",), "zeros", dtype="float32")}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def norm_spec(cfg, d=None) -> dict:
    d = d or cfg.d_model
    return rmsnorm_spec(d) if cfg.norm == "rmsnorm" else layernorm_spec(d)


def apply_norm(cfg, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# RoPE (partial-fraction support)
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float, fraction: float = 1.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    rot = int(d * fraction) // 2 * 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None, None].astype(jnp.float32) * freqs  # (..., S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_embed(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = 10_000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs.  Gated variants use ONE fused (d, 2f) weight: the shared-input case of
# horizontal fusion (DESIGN.md §4.1) — gate and up matmuls become one kernel.
# ---------------------------------------------------------------------------
def mlp_spec(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.activation in ("silu", "gelu"):       # gated (SwiGLU / GeGLU)
        return {"w_in": ParamSpec((d, 2 * f), ("embed", "ffn")),
                "w_out": ParamSpec((f, d), ("ffn", "embed"), "out_proj")}
    return {"w_in": ParamSpec((d, f), ("embed", "ffn")),
            "w_out": ParamSpec((f, d), ("ffn", "embed"), "out_proj")}


def mlp(cfg, p, x, d_ff: Optional[int] = None):
    act = cfg.activation
    h = x @ p["w_in"]
    if act in ("silu", "gelu"):
        gate, up = jnp.split(h, 2, axis=-1)
        g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
        h = g * up
    elif act == "gelu_mlp":
        h = jax.nn.gelu(h)
    elif act == "relu2_mlp":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(act)
    h = shard(h, ("batch", "seq", "act_ffn"))
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# GQA attention — blockwise (flash-style) for train/prefill
# ---------------------------------------------------------------------------
def attn_spec(cfg) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    # fused QKV projection: horizontal fusion of the three shared-input matmuls
    return {"w_qkv": ParamSpec((d, (H + 2 * Hkv) * Dh), ("embed", "qkv")),
            "w_o": ParamSpec((H * Dh, d), ("qkv", "embed"), "out_proj")}


def qkv_project(cfg, p, x):
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    qkv = x @ p["w_qkv"]
    q = qkv[..., : H * Dh].reshape(B, S, H, Dh)
    k = qkv[..., H * Dh: (H + Hkv) * Dh].reshape(B, S, Hkv, Dh)
    v = qkv[..., (H + Hkv) * Dh:].reshape(B, S, Hkv, Dh)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,Sq,H,D), k: (B,Sk,Hkv,D) -> scores (B,Hkv,rep,Sq,Sk) fp32."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Sq, Hkv, rep, D)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k, preferred_element_type=jnp.float32)
    return s * (1.0 / math.sqrt(D))


def _gqa_out(w, v):
    """w: (B,Hkv,rep,Sq,Sk) fp32, v: (B,Sk,Hkv,D) -> (B,Sq,H,D)."""
    B, Hkv, rep, Sq, Sk = w.shape
    o = jnp.einsum("bhrqk,bkhd->bqhrd", w.astype(v.dtype), v)
    return o.reshape(B, Sq, Hkv * rep, v.shape[-1])


def blockwise_attention(q, k, v, *, causal=True, q_offset=0,
                        chunk_q=1024, chunk_k=1024):
    """Flash-style attention in jnp: scan over KV chunks with running
    (max, sum, acc); never materializes (Sq, Sk)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    rep = H // Hkv
    cq, ck = min(chunk_q, Sq), min(chunk_k, Sk)
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, cq, Sk, ck)
    nq, nk = Sq // cq, Sk // ck

    qc = q.reshape(B, nq, cq, H, D)
    kc = k.reshape(B, nk, ck, Hkv, D)
    vc = v.reshape(B, nk, ck, Hkv, Dv)
    qpos = q_offset + jnp.arange(Sq).reshape(nq, cq)

    def kv_step(carry, ik):
        m, l, acc = carry                      # (B,Hkv,rep,nq,cq) fp32 / acc (+D)
        kb = jax.lax.dynamic_index_in_dim(kc, ik, 1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vc, ik, 1, keepdims=False)
        kpos = ik * ck + jnp.arange(ck)
        # scores for every q chunk at once: (B,Hkv,rep,nq,cq,ck)
        qg = qc.reshape(B, nq, cq, Hkv, rep, D)
        s = jnp.einsum("bnqhrd,bkhd->bhrnqk", qg, kb,
                       preferred_element_type=jnp.float32) / math.sqrt(D)
        if causal:
            mask = qpos[:, :, None] >= kpos[None, None, :]     # (nq,cq,ck)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        scale = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * scale + p.sum(axis=-1)
        pv = jnp.einsum("bhrnqk,bkhd->bhrnqd", p.astype(vb.dtype), vb)
        acc_new = acc * scale[..., None].astype(acc.dtype) + pv.astype(acc.dtype)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, rep, nq, cq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, nq, cq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, rep, nq, cq, Dv), jnp.float32)
    (m, l, acc), _ = maybe_scan(kv_step, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B,Hkv,rep,nq,cq,Dv) -> (B,Sq,H,Dv)
    out = out.transpose(0, 3, 4, 1, 2, 5).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def local_attention(q, k, v, window: int, *, q_offset=0):
    """Sliding-window causal attention, banded blockwise: q chunk i attends
    kv chunks {i-1, i} with chunk size == window.  O(S·2W·D) flops."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    W = min(window, S)
    pad = (-S) % W
    if pad:
        zq = jnp.zeros((B, pad, H, D), q.dtype)
        zk = jnp.zeros((B, pad, Hkv, D), k.dtype)
        q = jnp.concatenate([q, zq], 1)
        k = jnp.concatenate([k, zk], 1)
        v = jnp.concatenate([v, zk], 1)
    Sp = q.shape[1]
    n = Sp // W
    qc = q.reshape(B, n, W, H, D)
    kc = k.reshape(B, n, W, Hkv, D)
    vc = v.reshape(B, n, W, Hkv, D)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], 1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], 1)
    kk = jnp.concatenate([k_prev, kc], 2)          # (B,n,2W,Hkv,D)
    vv = jnp.concatenate([v_prev, vc], 2)
    qg = qc.reshape(B, n, W, Hkv, rep, D)
    s = jnp.einsum("bnqhrd,bnkhd->bnhrqk", qg, kk,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    # band mask: key j (global idx in window coords) valid iff
    #   q_idx - W < j_rel - W <= q_idx  =>  causal within [q-W+1 .. q]
    qi = jnp.arange(W)[:, None]
    kj = jnp.arange(2 * W)[None, :] - W            # relative to chunk start
    mask = (kj <= qi) & (kj > qi - W)
    first = jnp.arange(n) == 0                     # chunk 0 has no prev chunk
    mask_first = mask & (kj[None] >= 0)
    full_mask = jnp.where(first[:, None, None], mask_first, mask[None])
    s = jnp.where(full_mask[None, :, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnhrqk,bnkhd->bnqhrd", w.astype(vv.dtype), vv)
    o = o.reshape(B, Sp, H, D)[:, :S]
    return o.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, window: Optional[int] = None):
    """Single-token decode vs a (possibly ring-buffer) cache.

    q: (B,1,H,D); k_cache/v_cache: (B,Smax,Hkv,D); cur_len: () int32 — number
    of valid tokens (for ring buffers: min(pos, W) handled by caller masks).
    """
    B, _, H, D = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Hkv, rep, D)
    s = jnp.einsum("bhrd,bkhd->bhrk", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    valid = jnp.arange(Smax)[None, None, None, :] < cur_len
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrk,bkhd->bhrd", w.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Embedding / heads
# ---------------------------------------------------------------------------
def embed_spec(cfg) -> dict:
    return {"embedding": ParamSpec((cfg.vocab_size, cfg.d_model),
                                   ("vocab", "embed"), "embed")}


def embed(p, tokens, d_model: int):
    x = jnp.take(p["embedding"], tokens, axis=0)
    return x * math.sqrt(d_model)


def embed_onehot(p, tokens, d_model: int):
    """Decode-path embedding lookup as one_hot @ table: with the table
    vocab-sharded, the contraction runs shard-local and the partitioner
    psums a (B, d) result (~MBs) instead of all-gathering the table
    (82 MB/chip/step at 256k vocab) — §Perf iteration 7.  Only used for
    single-token decode (one_hot of (B,) is cheap; never for (B,S) train)."""
    emb = p["embedding"]
    oh = jax.nn.one_hot(tokens, emb.shape[0], dtype=emb.dtype)
    return (oh @ emb) * math.sqrt(d_model)


def unembed(p, x, softcap: float = 0.0):
    logits = jnp.einsum("bsd,vd->bsv", x, p["embedding"],
                        preferred_element_type=jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def cross_entropy(logits, labels, mask=None, z_loss: float = 1e-4):
    """Mean token CE (fp32) with optional z-loss; labels<0 are ignored."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    valid = labels >= 0
    if mask is not None:
        valid = valid & mask
    denom = jnp.maximum(valid.sum(), 1)
    return jnp.where(valid, nll, 0.0).sum() / denom
