"""Property tests for distributed/compression.py — the int8-on-the-wire
gradient path must track the fp32 collective within the quantization
tolerance for *any* operand, not just the hand-picked fixtures:

  * ``compressed_allgather_mean`` (int8 all_gather + local dequant/mean)
    vs the fp32 ``pmean`` reference: per-element error ≤ mean_i(scale_i)/2
    — each member's dequant error is ≤ scale_i/2, and the mean averages
    the bounds.  Collectives are emulated with ``jax.vmap(axis_name=)``,
    so no mesh/device setup is needed.
  * quantize→dequantize roundtrip error ≤ scale/2 elementwise.
  * error feedback telescopes exactly: after T steps of
    ``compress_roundtrip`` the un-delivered mass IS the final residual.

Cases are generated from a seed (shapes, member counts, magnitudes over
six decades, all-zero and outlier-dominated specials).  Under Hypothesis
the seed space is fuzzed (shrinking on failure); the container pins no
hypothesis wheel, so a deterministic seed sweep covers the same
generator when the import is missing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import compression as comp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SEEDS = range(40)


def _grads(seed):
    """(n, *shape) float32 member gradients: random magnitudes across six
    decades plus the degenerate specials (all-zero -> the 1e-12 scale
    floor; one huge outlier -> one member's scale dwarfs the rest)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 5))
    shape = tuple(int(d) for d in
                  rng.integers(1, 8, size=int(rng.integers(1, 3))))
    mag = 10.0 ** rng.uniform(-3, 3)
    gs = (rng.standard_normal((n, *shape)) * mag).astype(np.float32)
    kind = seed % 5
    if kind == 0:
        gs[:] = 0.0
    elif kind == 1:
        gs[0].flat[0] = np.float32(1e4 * mag)
    return jnp.asarray(gs)


def _check_allgather_mean(seed):
    gs = _grads(seed)
    n = gs.shape[0]
    out = np.asarray(jax.vmap(
        lambda g: comp.compressed_allgather_mean(g, "pods"),
        axis_name="pods")(gs))
    ref = np.asarray(jax.vmap(
        lambda g: jax.lax.pmean(g.astype(jnp.float32), "pods"),
        axis_name="pods")(gs))
    # every member computes the identical mean (the gather is symmetric)
    assert out.shape == gs.shape
    np.testing.assert_array_equal(out, np.broadcast_to(out[0], out.shape))
    flat = np.abs(np.asarray(gs, np.float32)).reshape(n, -1)
    scales = np.maximum(flat.max(axis=1), 1e-12) / 127.0
    tol = scales.mean() / 2.0 * (1.0 + 1e-5) + 1e-12
    assert np.all(np.abs(out[0] - ref[0]) <= tol), \
        (seed, np.max(np.abs(out[0] - ref[0])), tol)


def _check_roundtrip(seed):
    g = _grads(seed)[0]
    q, scale = comp.quantize_int8(g)
    assert q.dtype == jnp.int8
    gh = np.asarray(comp.dequantize_int8(q, scale))
    tol = float(scale) / 2.0 * (1.0 + 1e-5) + 1e-12
    assert np.all(np.abs(gh - np.asarray(g, np.float32)) <= tol)


def _check_error_feedback_telescopes(seed):
    gs = _grads(seed)
    delivered, residual = [], None
    for g in gs:
        g_hat, residual = comp.compress_roundtrip(g, residual)
        delivered.append(np.asarray(g_hat, np.float64))
    total = np.asarray(gs, np.float64).sum(axis=0)
    undelivered = total - np.sum(delivered, axis=0)
    scale = max(float(np.max(np.abs(total))), 1.0)
    np.testing.assert_allclose(undelivered, np.asarray(residual, np.float64),
                               atol=scale * 1e-5)


if HAVE_HYPOTHESIS:
    _fuzz = lambda f: settings(max_examples=60, deadline=None)(
        given(st.integers(min_value=0, max_value=2**31 - 1))(f))

    @_fuzz
    def test_compressed_allgather_mean_tracks_fp32_psum(seed):
        _check_allgather_mean(seed)

    @_fuzz
    def test_int8_roundtrip_error_within_half_scale(seed):
        _check_roundtrip(seed)

    @_fuzz
    def test_error_feedback_residual_telescopes(seed):
        _check_error_feedback_telescopes(seed)
else:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_compressed_allgather_mean_tracks_fp32_psum(seed):
        _check_allgather_mean(seed)

    @pytest.mark.parametrize("seed", SEEDS)
    def test_int8_roundtrip_error_within_half_scale(seed):
        _check_roundtrip(seed)

    @pytest.mark.parametrize("seed", SEEDS)
    def test_error_feedback_residual_telescopes(seed):
        _check_error_feedback_telescopes(seed)
