import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory_analysis / cost_analysis / collective
bytes as JSON artifacts for §Dry-run and §Roofline of EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Exit code is non-zero if any attempted cell fails (sharding mismatch,
OOM at compile, unsupported collective) — those are bugs in the system.
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import runtime_flags

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.distributed import sharding as shd
from repro.distributed.hlo_analysis import (analyze_compiled,
                                            memory_analysis_dict)
from repro.launch import input_specs as ispecs
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.base import abstract_params, logical_axes
from repro.train import optimizer as opt_mod
from repro.train.train_loop import TrainConfig, make_train_step

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def model_flops(cfg, shape) -> float:
    """6·N_active·D (train) / 2·N_active·D (fwd-only), whole step, all chips."""
    n = lm.count_params(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch                      # one new token per seq
    return 2.0 * n * tokens


def build_cell(cfg, shape, mesh):
    """Returns (fn, args, in_shardings, donate) for lowering one cell."""
    rules = shd.rules_for(cfg, mesh, kind=shape.kind)
    specs = lm.param_specs(cfg)
    params_ab = abstract_params(specs, jnp.dtype(cfg.dtype))
    params_sh = shd.sharding_tree(params_ab, logical_axes(specs), mesh, rules)

    def batch_shardings(batch):
        out = {}
        for k, v in batch.items():
            if k == "pixel_embeds":
                ax = ("batch", None, None)
            elif v.ndim == 3:
                ax = ("batch", None, "seq")          # audio (B,K,S)
            elif v.ndim == 2:
                ax = ("batch", "seq")
            else:
                ax = ("batch",)
            out[k] = shd.NamedSharding(mesh, shd.resolve_pspec(ax, v.shape,
                                                               mesh, rules))
        return out

    ins = ispecs.input_specs(cfg, shape)
    if shape.kind == "train":
        tcfg = TrainConfig()
        step_fn = make_train_step(cfg, tcfg, mesh)
        # moments are fp32 but share the params' shapes => same shardings
        opt_sh = opt_mod.OptState(m=params_sh, v=params_sh,
                                  count=shd.replicated(mesh))
        opt_ab = opt_mod.OptState(
            m=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                           params_ab),
            v=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                           params_ab),
            count=jax.ShapeDtypeStruct((), jnp.int32))
        batch_ab = ins["batch"]
        args = (params_ab, opt_ab, batch_ab, jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (params_sh, opt_sh, batch_shardings(batch_ab),
                 shd.replicated(mesh))
        return step_fn, args, in_sh, (0, 1)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return lm.prefill(cfg, params, batch, max_len=shape.seq_len)
        batch_ab = ins["batch"]
        args = (params_ab, batch_ab)
        in_sh = (params_sh, batch_shardings(batch_ab))
        return prefill_step, args, in_sh, ()

    # decode
    if os.environ.get("REPRO_GREEDY_SERVE"):
        def serve_step(params, cache, tokens):
            return lm.serve_step_greedy(cfg, params, cache, tokens)
    else:
        def serve_step(params, cache, tokens):
            return lm.decode_step(cfg, params, cache, tokens)

    cache_ab = ins["cache"]
    cache_ax = lm.cache_logical_axes(cfg, shape.global_batch, shape.seq_len)
    cache_sh = shd.sharding_tree(cache_ab, cache_ax, mesh, rules)
    tok_ab = ins["tokens"]
    tok_sh = shd.NamedSharding(mesh, shd.resolve_pspec(
        ("batch",) + (None,) * (tok_ab.ndim - 1), tok_ab.shape, mesh, rules))
    args = (params_ab, cache_ab, tok_ab)
    in_sh = (params_sh, cache_sh, tok_sh)
    return serve_step, args, in_sh, (1,)


def scale_depth(cfg, depth: int):
    """PREFIX-truncated config (first `depth` layers).

    Exact-roofline path: lower unrolled at two prefix depths d1 < d2 chosen
    as 1 and 2 pattern *units* (dense: 1 layer; recurrentgemma: 3 (rec,rec,
    attn); xlstm: 8 (7 mLSTM + sLSTM); deepseek: the dense first layer lands
    in the shared overhead).  Then per-unit cost = (C(d2)-C(d1))/(units2-
    units1), total(L) = C(d1) + per_unit * (L-d1)/unit — exact because units
    are homogeneous by construction.  See EXPERIMENTS.md §Methodology.
    """
    pat = cfg.pattern
    L = len(pat)
    if depth >= L:
        return cfg
    new_pat = tuple(pat[:depth])
    overrides = {i: v for i, v in cfg.moe_layer_overrides.items() if i < depth}
    return dataclasses.replace(cfg, num_layers=depth, block_pattern=new_pat,
                               moe_layer_overrides=overrides,
                               name=f"{cfg.name}@L{depth}")


#: per-arch pattern-unit size for the two-point roofline extrapolation
PATTERN_UNIT = {"recurrentgemma-2b": 3, "xlstm-1.3b": 8}


def depth_pair(arch: str) -> tuple[int, int]:
    u = PATTERN_UNIT.get(arch, 1)
    base = 2 if u == 1 else u
    return base, 2 * base


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             depth: int = 0, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "unrolled": runtime_flags.UNROLL_SCANS, "depth": depth or cfg.num_layers,
           "full_depth": cfg.num_layers}
    if not ok:
        rec.update(status="SKIP", reason=why)
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}__{shape_name}__{mesh_kind}{tag}.json").write_text(
            json.dumps(rec, indent=1, default=float))
        return rec
    if depth:
        cfg = scale_depth(cfg, depth)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    rules = shd.rules_for(cfg, mesh, kind=shape.kind)
    t0 = time.time()
    try:
        with shd.use_sharding(mesh, rules):
            fn, args, in_sh, donate = build_cell(cfg, shape, mesh)
            jfn = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = memory_analysis_dict(compiled)
        roof = analyze_compiled(compiled, n_dev)
        mf = model_flops(cfg, shape)
        rec.update(
            status="OK",
            n_devices=n_dev,
            t_lower_s=round(t_lower, 2),
            t_compile_s=round(t_compile, 2),
            memory=mem,
            roofline=roof.summary(),
            model_flops_total=mf,
            model_flops_per_chip=mf / n_dev,
            useful_flops_ratio=(mf / n_dev) / max(roof.flops, 1.0),
        )
    except Exception as e:
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_dir.mkdir(parents=True, exist_ok=True)
    out = out_dir / f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    out.write_text(json.dumps(rec, indent=1, default=float))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer/KV scans so cost_analysis counts the "
                         "whole program (XLA counts while-loop bodies once); "
                         "exact roofline numbers at higher compile cost")
    ap.add_argument("--depth", type=int, default=0,
                    help="reduce layer count (pattern-preserving) — the "
                         "roofline pipeline lowers unrolled at two depths "
                         "and extrapolates per-layer costs linearly")
    ap.add_argument("--tag", default="",
                    help="artifact filename suffix (e.g. _d4 for depth runs)")
    args = ap.parse_args()
    runtime_flags.UNROLL_SCANS = bool(args.unroll or os.environ.get("REPRO_UNROLL"))

    archs = args.arch or (list_archs() if args.all else [])
    shapes = args.shape or list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not archs:
        ap.error("pass --arch <id> (repeatable) or --all")

    out_dir = Path(args.out)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                tgt = out_dir / f"{arch}__{shape_name}__{mesh_kind}{args.tag}.json"
                if args.skip_existing and tgt.exists():
                    rec = json.loads(tgt.read_text())
                    if rec.get("status") in ("OK", "SKIP"):
                        print(f"[cached] {arch} {shape_name} {mesh_kind}: "
                              f"{rec['status']}", flush=True)
                        continue
                t0 = time.time()
                rec = run_cell(arch, shape_name, mesh_kind, out_dir,
                               depth=args.depth, tag=args.tag)
                dt = time.time() - t0
                if rec["status"] == "OK":
                    r = rec["roofline"]
                    print(f"[{rec['status']}] {arch} {shape_name} {mesh_kind} "
                          f"({dt:.0f}s): dominant={r['dominant']} "
                          f"tc={r['t_compute_s']:.3e} tm={r['t_memory_s']:.3e} "
                          f"tcoll={r['t_collective_s']:.3e}", flush=True)
                elif rec["status"] == "SKIP":
                    print(f"[SKIP] {arch} {shape_name} {mesh_kind}: "
                          f"{rec['reason'][:80]}", flush=True)
                else:
                    failures += 1
                    print(f"[FAIL] {arch} {shape_name} {mesh_kind}: "
                          f"{rec['error']}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
