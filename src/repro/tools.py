"""Operational tooling CLI.

  PYTHONPATH=src python -m repro.tools cache-inspect [--cache PATH] [--json]
  PYTHONPATH=src python -m repro.tools kv-inspect --snapshot PATH [--json]
  PYTHONPATH=src python -m repro.tools fit-cost [--history DIR] [--out PATH]
  PYTHONPATH=src python -m repro.tools mesh-inspect --mesh-shape N [--json]

``cache-inspect`` dumps the persistent schedule cache
(core/schedule_cache.py): one row per tuned bundle — members, mode,
schedule, predicted vs measured time and their delta — plus aggregate
stats: entry count vs the LRU bound, measured coverage, mean/max
|cm-vs-measured delta|, and *stale signatures* (entries never consulted
since they were recorded: the bundle shape they key no longer occurs in
any planned graph, so they are LRU-eviction candidates).

``kv-inspect`` reads a paged KV-pool snapshot (``launch/serve
--kv-snapshot PATH``, serve/kv_pool.py): arena occupancy (in-use vs free
vs evictable-cached blocks), the prefix-index counters (hits, tokens
reused, trie size, evictions, COW copies), and one row per batch slot
with its mapped block-table prefix.

``fit-cost`` distills the accumulated cm-vs-measured deltas in the CI
benchmark trajectory (``benchmarks/history/BENCH_measured_*.json``) into
a per-op-class correction table for the roofline cost model — clamped
medians of measured/predicted per class (core/cost_model.op_class).  The
table is inert until loaded ($REPRO_COST_CORRECTIONS=<path> or
``cost_model.set_corrections``); nothing in the default model changes.

``mesh-inspect`` reports the tensor-parallel serve topology without
running any requests: the device mesh, each planner-graph op's per-shard
operand shapes next to the single-device shapes, and which members of
the planned bundles are shard-local vs replicated.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import statistics
import sys


def _resolve_cache(path: str | None):
    from repro.core.schedule_cache import ScheduleCache, default_cache
    if path:
        return ScheduleCache(path)
    return default_cache()


def cache_inspect(args) -> int:
    cache = _resolve_cache(args.cache)
    rows = []
    for key, e in sorted(cache.entries.items()):
        if not isinstance(e, dict):
            continue
        m = cache.meta.get(key, {})
        rows.append({
            "key": key[:12],
            "members": "+".join(e.get("members", ["?"])),
            "mode": e.get("mode"),
            "sched": ":".join(str(r) for r in e.get("ratios", [])),
            "vmem_cap": e.get("vmem_cap"),
            "predicted_us": (None if e.get("predicted_s") is None
                             else round(e["predicted_s"] * 1e6, 2)),
            "measured_us": (None if e.get("measured_s") is None
                            else round(e["measured_s"] * 1e6, 2)),
            "delta_pct": (None if e.get("delta_pct") is None
                          else round(e["delta_pct"], 1)),
            "uses": m.get("uses", 0),
            "last_used": m.get("last_used", 0),
        })
    stats = cache.stats()
    stats["max_entries"] = cache.max_entries
    if args.json:
        print(json.dumps({"stats": stats, "entries": rows}, indent=1))
        return 0
    print(f"# schedule cache: {stats['path']}")
    if not rows:
        print("# (empty)")
        return 0
    cols = list(rows[0])
    widths = {c: max(len(c), *(len(str(r[c])) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r[c]).ljust(widths[c]) for c in cols))
    print(f"# {stats['entries']} entries"
          + (f" (bound {stats['max_entries']}, LRU)"
             if stats["max_entries"] else " (unbounded)")
          + f", {stats['measured']} measured, "
          f"{stats['stale_never_reused']} stale (never re-consulted)")
    if stats["mean_abs_delta_pct"] is not None:
        print(f"# cm-vs-measured |delta|: mean "
              f"{stats['mean_abs_delta_pct']:.1f}% "
              f"max {stats['max_abs_delta_pct']:.1f}%")
    return 0


def kv_inspect(args) -> int:
    with open(args.snapshot) as fh:
        snap = json.load(fh)
    if args.json:
        print(json.dumps(snap, indent=1))
        return 0
    nb, bs = snap["num_blocks"], snap["block_size"]
    slots = snap["slots"]
    usable = nb - slots
    used = snap["blocks_in_use"]
    print(f"# kv pool: {nb} blocks x {bs} tokens "
          f"({slots} sentinels, {usable} usable)")
    print(f"# occupancy: {used}/{usable} in use "
          f"({used / max(usable, 1):.0%}), {snap['free_blocks']} free, "
          f"{snap['evictable_blocks']} cached-evictable")
    print(f"# prefix index: {snap['trie_nodes']} trie nodes, "
          f"{snap['prefix_hits']} hits, "
          f"{snap['prefix_tokens_reused']} tokens reused, "
          f"{snap['evictions']} evictions, "
          f"{snap['cow_copies']} cow copies")
    rows = [{"slot": t["slot"], "owned": t["owned"],
             "tokens": t["owned"] * bs,
             "blocks": ",".join(str(b) for b in t["blocks"]) or "-"}
            for t in snap["tables"]]
    cols = list(rows[0])
    widths = {c: max(len(c), *(len(str(r[c])) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r[c]).ljust(widths[c]) for c in cols))
    return 0


def fit_cost(args) -> int:
    from repro.core.cost_model import CORRECTION_CLAMP, op_class
    files = sorted(glob.glob(os.path.join(args.history,
                                          "BENCH_measured_*.json")))
    deltas: dict[str, list[float]] = {}
    n_rows = 0
    for path in files:
        try:
            with open(path) as fh:
                report = json.load(fh)
        except (OSError, json.JSONDecodeError):
            continue
        for row in report.get("rows", []):
            d = row.get("cm_vs_measured_delta_pct")
            if d is None or not row.get("bundle"):
                continue
            n_rows += 1
            # the bundle's disagreement is attributed to every member's
            # class — per-member deltas aren't observable from a fused
            # measurement, so each class accumulates the deltas of every
            # bundle it took part in and the median washes out partners
            for member in str(row["bundle"]).split("+"):
                deltas.setdefault(op_class(member), []).append(float(d))
    lo, hi = CORRECTION_CLAMP
    classes = {
        cls: {
            "correction": round(
                min(hi, max(lo, 1.0 + statistics.median(ds) / 100.0)), 4),
            "n": len(ds),
            "median_delta_pct": round(statistics.median(ds), 2),
        }
        for cls, ds in sorted(deltas.items())
    }
    table = {"classes": classes, "clamp": [lo, hi],
             "source_files": len(files), "rows": n_rows}
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(table, fh, indent=1)
            fh.write("\n")
    if args.json:
        print(json.dumps(table, indent=1))
        return 0
    print(f"# fit-cost: {n_rows} measured rows in {len(files)} history "
          f"files under {args.history}")
    if not classes:
        print("# (no cm_vs_measured_delta_pct data — table is empty; the "
              "cost model stays purely analytic)")
    for cls, e in classes.items():
        print(f"  {cls:<32} x{e['correction']:<7} "
              f"(median delta {e['median_delta_pct']:+.1f}%, n={e['n']})")
    if args.out:
        print(f"# wrote {args.out} — activate with "
              f"REPRO_COST_CORRECTIONS={args.out}")
    return 0


def mesh_inspect(args) -> int:
    # XLA_FLAGS must be set before jax imports; tools.py imports jax lazily
    # for exactly this reason.
    n = args.mesh_shape
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}").strip()
    import dataclasses

    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import PrefillBudget, ServeEngine

    devs = jax.devices()
    if len(devs) < n:
        print(f"error: mesh shape {n} needs {n} devices, have {len(devs)} "
              f"(set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
              f"before launch)", file=sys.stderr)
        return 1
    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    mesh = Mesh(np.array(devs)[:n], (args.shard_axis,))
    kw = dict(batch=args.batch, max_len=args.max_len,
              scheduling="continuous", plan_fusion=True,
              prefill_budget=PrefillBudget(chunk_rows=args.chunk_rows))
    tp = ServeEngine(cfg, params, mesh=mesh, shard_axis=args.shard_axis,
                     **kw)
    ref = ServeEngine(cfg, params, **kw)
    chunks = tp.prefill_budget.max_coresident_chunks
    g_tp = tp.decode_graph(prefill_chunks=chunks)
    g_ref = ref.decode_graph(prefill_chunks=chunks)

    def operand_shapes(op):
        return [list(o.shape) for o in (*op.inputs, *op.outputs)]

    ops = []
    sharded_names = set()
    # both graphs come from the same builder with the same chunk count, so
    # they align positionally; an op whose operand shapes shrank under the
    # shard-local head/FFN widths is shard-local, the rest are replicated
    for gt, gr in zip(g_tp, g_ref):
        local = operand_shapes(gt.op)
        full = operand_shapes(gr.op)
        sharded = local != full
        if sharded:
            sharded_names.add(gt.op.name)
        ops.append({"op": gt.op.name, "grid": gt.op.grid,
                    "bound": gt.op.bound, "sharded": sharded,
                    "per_shard_shapes": local,
                    "single_device_shapes": full})
    # plan with the executed serve path's options (allow_same_bound: at
    # smoke scale everything is memory-bound and launch amortization still
    # decides), so the bundle report matches the program the engine runs
    from repro.core import planner
    plan = planner.plan(g_tp, max_ways=max(3, 2 + chunks),
                        allow_same_bound=True, mesh_tag=tp._mesh_tag)

    def members_of(row):
        # a stitched chain member is shard-local if any stitched op is
        return [{"member": m,
                 "sharded": any(p in sharded_names
                                for p in m.split("→"))}
                for m in row["members"].split("+")]

    bundles = [{"members": members_of(row), "schedule": row["schedule"]}
               for row in plan.summary()]
    out = {
        "mesh": {"shape": dict(mesh.shape), "axis": args.shard_axis,
                 "devices": [str(d) for d in mesh.devices.ravel()]},
        "tp_shards": tp.tp_shards,
        "mesh_tag": tp._mesh_tag,
        "ops": ops,
        "bundles": bundles,
    }
    if args.json:
        print(json.dumps(out, indent=1))
        return 0
    print(f"# mesh: {dict(mesh.shape)} over {len(mesh.devices.ravel())} "
          f"devices (axis '{args.shard_axis}', cache tag "
          f"'{tp._mesh_tag}')")
    print(f"# per-shard planner graph ({len(ops)} ops):")
    for o in ops:
        kind = "shard-local" if o["sharded"] else "replicated "
        shapes = " ".join("x".join(str(d) for d in s)
                          for s in o["per_shard_shapes"])
        print(f"  {kind}  {o['op']:<34} grid={o['grid']:<5} "
              f"{o['bound']:<7} {shapes}")
    print("# planned bundles (per shard — SPMD traces one program per "
          "shard):")
    for b in bundles:
        tags = ", ".join(
            f"{m['member']}[{'local' if m['sharded'] else 'repl'}]"
            for m in b["members"])
        print(f"  sched {b['schedule']:<9} {tags}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tools")
    sub = ap.add_subparsers(dest="cmd", required=True)
    ci = sub.add_parser("cache-inspect",
                        help="dump the persistent schedule cache")
    ci.add_argument("--cache", default=None,
                    help="cache file (default: the shared default cache — "
                         "$REPRO_SCHEDULE_CACHE with its LRU bound)")
    ci.add_argument("--json", action="store_true")
    ci.set_defaults(fn=cache_inspect)
    ki = sub.add_parser("kv-inspect",
                        help="dump a paged KV-pool snapshot "
                             "(launch/serve --kv-snapshot)")
    ki.add_argument("--snapshot", required=True,
                    help="snapshot JSON written by launch/serve "
                         "--kv-snapshot PATH")
    ki.add_argument("--json", action="store_true")
    ki.set_defaults(fn=kv_inspect)
    fc = sub.add_parser("fit-cost",
                        help="fit per-op-class cost-model corrections from "
                             "the benchmark history")
    fc.add_argument("--history", default="benchmarks/history",
                    help="directory holding BENCH_measured_*.json reports")
    fc.add_argument("--out", default=None,
                    help="write the correction table here (activate via "
                         "REPRO_COST_CORRECTIONS=PATH)")
    fc.add_argument("--json", action="store_true")
    fc.set_defaults(fn=fit_cost)
    mi = sub.add_parser("mesh-inspect",
                        help="report the tensor-parallel serve topology "
                             "(mesh, per-shard shapes, bundle locality)")
    mi.add_argument("--arch", default="granite-3-2b")
    mi.add_argument("--mesh-shape", type=int, default=4,
                    help="devices along the shard axis (fake CPU devices "
                         "are forced if XLA_FLAGS doesn't already)")
    mi.add_argument("--shard-axis", default="model")
    mi.add_argument("--batch", type=int, default=2)
    mi.add_argument("--max-len", type=int, default=48)
    mi.add_argument("--chunk-rows", type=int, default=8)
    mi.add_argument("--json", action="store_true")
    mi.set_defaults(fn=mesh_inspect)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
