"""Griffin recurrent block: fused input projections + causal depthwise conv +
RG-LRU gated linear recurrence (arXiv:2402.19427).

TPU adaptation of the recurrence: a first-order diagonal linear recurrence
h_t = a_t * h_{t-1} + b_t is evaluated with ``jax.lax.associative_scan``
(O(log S) depth — the Blelloch scan maps well onto the VPU), instead of the
sequential CUDA scan the reference GPU implementation uses.  Decode is the
O(1) single-step update.

The two input projections (gate branch + recurrent branch) are emitted as ONE
fused (d, 2·lru) matmul — the shared-input horizontal-fusion case.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec

C_EXP = 8.0  # RG-LRU exponent constant


def spec(cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    H = cfg.num_heads
    bd = w // H                      # block-diagonal gate blocks (per head)
    return {
        "w_in": ParamSpec((d, 2 * w), ("embed", "ffn")),      # [gate | recurrent]
        "conv_w": ParamSpec((cfg.conv1d_width, w), (None, "lru")),
        "conv_b": ParamSpec((w,), ("lru",), "zeros"),
        "gate_a": ParamSpec((H, bd, bd), (None, "lru", None)),
        "gate_a_b": ParamSpec((w,), ("lru",), "zeros"),
        "gate_x": ParamSpec((H, bd, bd), (None, "lru", None)),
        "gate_x_b": ParamSpec((w,), ("lru",), "zeros"),
        "lam": ParamSpec((w,), ("lru",), "ones", dtype="float32"),
        "w_out": ParamSpec((w, d), ("ffn", "embed"), "out_proj"),
    }


def _block_diag(x, w, b):
    """x: (..., W) with W = H*bd; w: (H, bd, bd) -> (..., W)."""
    H, bd, _ = w.shape
    xh = x.reshape(x.shape[:-1] + (H, bd))
    y = jnp.einsum("...hi,hij->...hj", xh, w)
    return y.reshape(x.shape) + b


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B,S,W), w: (K,W)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(K))
    return y + b


def _gates(p, rec):
    """RG-LRU gate math. rec: (B,S,W) -> (log_a fp32, gated_in fp32)."""
    r = jax.nn.sigmoid(_block_diag(rec, p["gate_a"], p["gate_a_b"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(rec, p["gate_x"], p["gate_x_b"]).astype(jnp.float32))
    # a = sigmoid(lam) ** (c*r)  =>  log_a = -c * r * softplus(-lam)
    log_a = -C_EXP * r * jax.nn.softplus(-p["lam"])
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-6)) * (i * rec.astype(jnp.float32))
    return log_a, gated


def rg_lru_scan(p, rec, h0=None):
    """Full-sequence RG-LRU via associative scan.
    rec: (B,S,W); h0: (B,W) initial state -> (y (B,S,W), h_last (B,W))."""
    log_a, b = _gates(p, rec)
    a = jnp.exp(log_a)
    if h0 is not None:
        # fold initial state into the first step: b_0 += a_0 * h0
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(rec.dtype), h[:, -1, :]


def rg_lru_step(p, rec_t, h_prev):
    """Single decode step. rec_t: (B,W); h_prev: (B,W) fp32."""
    log_a, b = _gates(p, rec_t[:, None, :])
    h = jnp.exp(log_a[:, 0]) * h_prev + b[:, 0]
    return h.astype(rec_t.dtype), h


def apply_train(cfg, p, x, h0=None, conv0=None):
    """Full block, full sequence.  x: (B,S,d).
    Returns (y, (h_last, conv_tail)) for cache handoff at prefill."""
    gate_in, rec_in = jnp.split(x @ p["w_in"], 2, axis=-1)
    gate = jax.nn.gelu(gate_in)
    if conv0 is not None:
        rec_cat = jnp.concatenate([conv0.astype(rec_in.dtype), rec_in], axis=1)
        rec = _causal_conv(rec_cat, p["conv_w"], p["conv_b"])[:, conv0.shape[1]:]
    else:
        rec = _causal_conv(rec_in, p["conv_w"], p["conv_b"])
    y, h_last = rg_lru_scan(p, rec, h0)
    K = cfg.conv1d_width
    conv_tail = rec_in[:, -(K - 1):, :] if rec_in.shape[1] >= K - 1 else rec_in
    return (y * gate) @ p["w_out"], (h_last, conv_tail)


def apply_decode(cfg, p, x_t, h_prev, conv_buf):
    """One step.  x_t: (B,1,d); h_prev: (B,W) fp32; conv_buf: (B,K-1,W)."""
    gate_in, rec_in = jnp.split(x_t @ p["w_in"], 2, axis=-1)
    gate = jax.nn.gelu(gate_in[:, 0])
    K = cfg.conv1d_width
    window = jnp.concatenate([conv_buf.astype(rec_in.dtype), rec_in], axis=1)  # (B,K,W)
    rec_t = jnp.einsum("bkw,kw->bw", window, p["conv_w"]) + p["conv_b"]
    y_t, h_new = rg_lru_step(p, rec_t, h_prev)
    new_buf = window[:, 1:, :].astype(conv_buf.dtype)
    out = ((y_t * gate) @ p["w_out"])[:, None, :]
    return out, h_new, new_buf
