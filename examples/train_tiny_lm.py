"""End-to-end training driver: train a reduced GQA LM for a few hundred
steps on CPU with the full production substrate — deterministic sharded data
pipeline, AdamW, grad clipping, async fault-tolerant checkpointing, straggler
watchdog, restart-on-failure — then kill it halfway and prove the resume
reproduces the uninterrupted run.

  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""
import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args()

    half = args.steps // 2
    with tempfile.TemporaryDirectory() as ckpt:
        print(f"=== phase 1: train to step {half}, checkpointing ===")
        losses = train_cli.main([
            "--arch", args.arch, "--scale", "smoke",
            "--steps", str(half), "--batch", "8", "--seq", "128",
            "--lr", "3e-3",
            "--ckpt-dir", ckpt, "--ckpt-every", str(max(1, half // 2)),
            "--log-every", str(max(1, args.steps // 10))])
        print(f"\n=== phase 2: 'crash', resume, continue to {args.steps} ===")
        losses2 = train_cli.main([
            "--arch", args.arch, "--scale", "smoke",
            "--steps", str(args.steps), "--batch", "8", "--seq", "128",
            "--lr", "3e-3",
            "--ckpt-dir", ckpt, "--resume",
            "--log-every", str(max(1, args.steps // 10))])
        tail = sum(losses2[-5:]) / len(losses2[-5:])
        assert losses[0] > tail, (losses[0], tail)
        print(f"\nloss {losses[0]:.3f} -> {tail:.3f} over "
              f"{args.steps} steps (with a restart at {half}); resume OK")


if __name__ == "__main__":
    main()
