"""Decode attention Pallas kernel: one new token vs a long KV cache.

Memory-bound par excellence (streams the whole cache, does O(D) flops per
byte) — the framework's Ethash: the canonical horizontal-fusion partner for
compute-bound matmuls in the dual-stream decode mode (serve/dual_stream.py).

Fusible form: 1-D grid over (batch, kv-chunk) linearized; the online-softmax
(m, l) carries live in small fp32 *outputs* with constant index maps (not
scratch) so the op composes under core/hfuse.generate.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.op_spec import OpSpec, Operand

NEG_INF = -1e30


def decode_attention_op(B: int, S: int, H: int, Hkv: int, D: int,
                        dtype=jnp.bfloat16, ck: int = 1024,
                        length=None, dynamic_length: bool = False) -> OpSpec:
    """q: (B,H,D); cache k,v: (B,S,Hkv,D); out o: (B,H,D) fp32.

    Grid: B * (S // ck) steps, batch-major.  `length` (static) masks the
    valid cache prefix; None = full cache.  ``dynamic_length`` instead adds
    a tiny (B, 1) int32 operand ("len", one row per batch slot, fetched as a
    (1, 1) block by the batch-major index map) holding each slot's valid
    prefix, so one compiled kernel serves every decode position of every
    slot independently — the form the executor binds to a live per-slot
    ``pos + 1`` vector (continuous batching: slots advance, finish and
    refill at unrelated cache positions within one launch).
    """
    assert S % ck == 0 and H % Hkv == 0
    assert not (dynamic_length and length is not None)
    nk = S // ck
    rep = H // Hkv
    scale = 1.0 / math.sqrt(D)
    valid_len = S if length is None else int(length)

    def body(step, *refs):
        if dynamic_length:
            len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref = refs
            cur_len = len_ref[0, 0]
        else:
            q_ref, k_ref, v_ref, o_ref, m_ref, l_ref = refs
            cur_len = valid_len
        j = step % nk

        @pl.when(j == 0)
        def _():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            o_ref[...] = jnp.zeros_like(o_ref)

        q = q_ref[0].astype(jnp.float32) * scale          # (H, D)
        k = k_ref[0].astype(jnp.float32)                  # (ck, Hkv, D)
        v = v_ref[0].astype(jnp.float32)
        qg = q.reshape(Hkv, rep, D)
        s = jnp.einsum("hrd,khd->hrk", qg, k)             # (Hkv, rep, ck)
        kpos = j * ck + jax.lax.broadcasted_iota(jnp.int32, (Hkv, rep, ck), 2)
        s = jnp.where(kpos < cur_len, s, NEG_INF)
        m_prev = m_ref[0]                                 # (H, 1)
        m_new = jnp.maximum(m_prev, s.reshape(H, ck).max(-1, keepdims=True))
        p = jnp.exp(s.reshape(H, ck) - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * alpha + p.sum(-1, keepdims=True)
        pv = jnp.einsum("hrk,khd->hrd", p.reshape(Hkv, rep, ck), v)
        o_ref[0] = o_ref[0] * alpha + pv.reshape(H, D)
        m_ref[0] = m_new

        @pl.when(j == nk - 1)
        def _():
            o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)

    itemsize = jnp.dtype(dtype).itemsize
    len_in = ((Operand((B, 1), jnp.int32, (1, 1), lambda s: (s // nk, 0)),)
              if dynamic_length else ())
    return OpSpec(
        name=f"decode_attn_B{B}_S{S}_H{H}kv{Hkv}", grid=B * nk, body=body,
        inputs=len_in
        + (Operand((B, H, D), dtype, (1, H, D), lambda s: (s // nk, 0, 0)),
           Operand((B, S, Hkv, D), dtype, (1, ck, Hkv, D),
                   lambda s: (s // nk, s % nk, 0, 0)),
           Operand((B, S, Hkv, D), dtype, (1, ck, Hkv, D),
                   lambda s: (s // nk, s % nk, 0, 0))),
        outputs=(Operand((B, H, D), jnp.float32, (1, H, D),
                         lambda s: (s // nk, 0, 0)),
                 Operand((B, H, 1), jnp.float32, (1, H, 1),
                         lambda s: (s // nk, 0, 0)),
                 Operand((B, H, 1), jnp.float32, (1, H, 1),
                         lambda s: (s // nk, 0, 0))),
        flops=2.0 * B * H * valid_len * D * 2,
        hbm_bytes=2.0 * B * valid_len * Hkv * D * itemsize
        + 2.0 * B * H * D * itemsize,
        tag="framework:decode_attention",
        in_names=(("len",) if dynamic_length else ()) + ("q", "k", "v"),
        out_names=("o", "m", "l"))
