"""Benchmark driver — one section per paper table/figure + the framework
integration table + the N-way bundle sweep + the roofline summary.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--smoke]
      [--measure interpret|device]

``--smoke`` runs just one tiny fused pair and one tiny 3-way bundle in
interpret mode with numerics checks — the CI guard that keeps the
benchmark code paths from rotting without paying for the full sweep.

``--measure`` additionally runs the measured-mode autotune report
(benchmarks/measured.py): two-stage top-K + coordinate-descent search with
a real measurement callable, emitting ``BENCH_measured_*.json`` with
predicted-vs-measured columns (uploaded as a CI artifact).

Time columns are cost-model derived over exact FLOP/byte counts (TPU v5e
targets; this host is CPU-only — see benchmarks/common.py §Methodology);
every HFuse row's kernel is numerics-verified in interpret mode.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def smoke() -> None:
    """One tiny fused pair + one tiny 3-way bundle, interpret mode."""
    from benchmarks.common import check_bundle_numerics, check_pair_numerics
    from repro.core.cost_model import Schedule
    from repro.kernels import paper_suite as ps

    opA, mkA, refA = ps.make_maxpool(**ps.SMALL_KW["maxpool"])
    opB, mkB, refB = ps.make_sha_like(**ps.SMALL_KW["sha_like"])
    err = check_pair_numerics(opA, mkA, refA, opB, mkB, refB, Schedule(1, 1))
    assert err < 2e-2, f"pair smoke numerics: {err}"
    print(f"# smoke pair maxpool+sha_like: max_err {err:.1e}")

    names = ps.paper_triples()[0]
    ops, mks, refs = ps.make_bundle(names, small=True)
    err3 = check_bundle_numerics(ops, mks, refs, Schedule((1,) * len(ops)))
    assert err3 < 2e-2, f"bundle smoke numerics: {err3}"
    print(f"# smoke bundle {'+'.join(names)}: max_err {err3:.1e}")
    print("SMOKE OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip interpret-mode numerics verification")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny pair + 3-way bundle with numerics, then exit "
                         "(the CI benchmark-smoke job)")
    ap.add_argument("--measure", choices=["interpret", "device", "auto"],
                    default=None,
                    help="run the measured-mode autotune report "
                         "(BENCH_measured_*.json; 'device' = auto-detected "
                         "TPU/GPU wall clock, 'interpret' = CI proxy)")
    ap.add_argument("--execute-plan", action="store_true",
                    help="run the executed-plan report: train-update and "
                         "serve-decode programs lowered by core/executor, "
                         "verified + timed on live operands "
                         "(BENCH_executed_*.json)")
    args = ap.parse_args()

    if args.measure:
        from repro.core.timing import resolve_backend
        backend = resolve_backend(
            "auto" if args.measure == "device" else args.measure)

    if args.smoke:
        smoke()
        if args.measure:
            from benchmarks import measured
            measured.run(backend, small=True)
        if args.execute_plan:
            from benchmarks import executed
            executed.run(backend if args.measure else "interpret")
        return

    if args.measure:
        from benchmarks import measured
        # interpret (incl. auto-resolved on CPU) can't execute full-size ops
        measured.run(backend, small=(backend == "interpret"))

    if args.execute_plan:
        from benchmarks import executed
        executed.run(backend if args.measure else "interpret")

    from benchmarks import fig7_pairs, fig8_kernels, fig9_fused, fig_framework
    from benchmarks import roofline

    print("# === fig8: individual kernel metrics (paper Fig. 8) ===")
    t0 = time.time()
    fig8_kernels.run()
    print(f"# fig8 done in {time.time() - t0:.1f}s\n")

    print("# === fig7: 16 pairs x workload ratios (paper Fig. 7) ===")
    t0 = time.time()
    fig7_pairs.run(check_numerics=not args.fast)
    print(f"# fig7 done in {time.time() - t0:.1f}s\n")

    print("# === fig7-nway: pair-vs-triple bundles (beyond paper) ===")
    t0 = time.time()
    fig7_pairs.run_nway(check_numerics=not args.fast)
    print(f"# fig7-nway done in {time.time() - t0:.1f}s\n")

    print("# === fig9: fused metrics ±VMEM cap (paper Fig. 9, RegCap) ===")
    t0 = time.time()
    fig9_fused.run()
    print(f"# fig9 done in {time.time() - t0:.1f}s\n")

    print("# === framework integration (beyond-paper; DESIGN.md §4) ===")
    t0 = time.time()
    fig_framework.run()
    print(f"# framework done in {time.time() - t0:.1f}s\n")

    print("# === roofline summary (from dry-run artifacts; §Roofline) ===")
    t0 = time.time()
    roofline.run()
    print(f"# roofline done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
