"""Batched serving engine (iteration-level batching with refill).

Semantics: up to ``batch`` requests run in lock-step — prompts are
right-aligned/padded, prefilled with the batched ``lm.prefill``, then decoded
together; finished sequences are masked out and the batch refills at the next
wavefront.  Per-slot-position continuous batching would need a vectorized
cache position (B,) — noted as an extension in DESIGN.md; iteration-level
batching is what the assigned decode shapes (uniform context length) model.

On the production mesh the cache is sequence-sharded and decode attention is
the distributed flash-decode (DESIGN.md §7).  ``examples/dual_stream_decode.py``
shows the horizontal-fusion dual-stream variant of the decode step.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_token: Optional[int] = None
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch: int = 8,
                 max_len: int = 512, rng_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.rng = jax.random.PRNGKey(rng_seed)
        self._decode = jax.jit(
            lambda p, c, t: lm.decode_step(cfg, p, c, t))
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(cfg, p, b, max_len=self.max_len))

    # ------------------------------------------------------------------
    def _prefill_wave(self, wave: list[Request]):
        """Waves are grouped by prompt length (see run()); empty slots
        duplicate row 0 and are ignored."""
        S = len(wave[0].prompt)
        toks = np.zeros((self.batch, S), np.int32)
        for i, r in enumerate(wave):
            toks[i] = r.prompt
        cache, last_logits = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        return cache, last_logits

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        if req.temperature > 0:
            self.rng, sub = jax.random.split(self.rng)
            return int(jax.random.categorical(
                sub, jnp.asarray(logits) / req.temperature))
        return int(logits.argmax())

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        # group by prompt length: one wave = one (length, <=batch) group
        by_len: dict[int, list[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        pending: list[list[Request]] = []
        for _, group in sorted(by_len.items()):
            for i in range(0, len(group), self.batch):
                pending.append(group[i: i + self.batch])
        while pending:
            wave = pending.pop(0)
            cache, last_logits = self._prefill_wave(wave)
            logits = np.asarray(last_logits, np.float32)
            for i, r in enumerate(wave):
                r.out_tokens.append(self._sample(logits[i], r))
            budget = max(r.max_new_tokens for r in wave)
            for _ in range(budget - 1):
                if all(r.done or len(r.out_tokens) >= r.max_new_tokens
                       for r in wave):
                    break
                toks = np.zeros((self.batch,), np.int32)
                for i, r in enumerate(wave):
                    toks[i] = r.out_tokens[-1]
                out, cache = self._decode(self.params, cache,
                                          jnp.asarray(toks))
                logits = np.asarray(out, np.float32)
                for i, r in enumerate(wave):
                    if r.done or len(r.out_tokens) >= r.max_new_tokens:
                        continue
                    tok = self._sample(logits[i], r)
                    r.out_tokens.append(tok)
                    if r.eos_token is not None and tok == r.eos_token:
                        r.done = True
            for r in wave:
                r.done = True
        return requests
