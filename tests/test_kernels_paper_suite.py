"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode),
across shapes and dtypes, for the 9 paper-analogue atoms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hfuse
from repro.kernels import paper_suite as ps

SHAPE_SWEEP = [(512, 256, 128), (1024, 512, 256), (2048, 128, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def run_and_check(op, mk, ref, key, tol):
    xs = mk(key)
    outs = hfuse.run_single(op, interpret=True)(*xs)
    want = ref(*xs)
    if not isinstance(want, (list, tuple)):
        want = (want,)
    for got, exp in zip(outs, want):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(exp, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("R,C,bm", SHAPE_SWEEP)
@pytest.mark.parametrize("name", ["maxpool", "upsample", "im2col"])
def test_elementwise_atoms(name, R, C, bm, dtype, rng):
    op, mk, ref = ps.ALL_KERNELS[name](R=R, C=C, dtype=dtype, bm=bm)
    run_and_check(op, mk, ref, rng, 1e-5 if dtype == jnp.float32 else 2e-2)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("R,C,bm", [(1024, 256, 128), (4096, 512, 512)])
def test_bnstats(R, C, bm, dtype, rng):
    op, mk, ref = ps.make_bnstats(R=R, C=C, dtype=dtype, bm=bm)
    tol = 1e-3 if dtype == jnp.float32 else 2.0   # bf16 sums over many rows
    run_and_check(op, mk, ref, rng, tol)


@pytest.mark.parametrize("R,C,bm", [(512, 128, 64), (1024, 256, 128)])
def test_hist(R, C, bm, rng):
    op, mk, ref = ps.make_hist(R=R, C=C, bm=bm)
    xs = mk(rng)
    outs = hfuse.run_single(op, interpret=True)(*xs)
    want = ref(*xs)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(want), atol=0.5)
    assert float(outs[0].sum()) == R * C          # every element counted once


@pytest.mark.parametrize("name", ["sha_like", "blake_like", "blake2b_like"])
def test_hash_like(name, rng):
    op, mk, ref = ps.CRYPTO_KERNELS[name](R=1024, bm=256)
    run_and_check(op, mk, ref, rng, 1e-5)
    assert op.bound == "compute"


def test_ethash_like(rng):
    op, mk, ref = ps.make_ethash_like(R_dag=4096, bm=256)
    run_and_check(op, mk, ref, rng, 1e-4)
    assert op.bound == "memory"


def test_paper_pairs_structure():
    pairs = ps.paper_pairs()
    assert len(pairs) == 16                       # 10 DL + 6 crypto (Fig. 7)
    dl = set(ps.DL_KERNELS)
    assert sum(1 for a, b in pairs if a in dl and b in dl) == 10


def test_resource_profiles_match_paper_table():
    """Fig. 8 structure: Ethash memory-bound, hashes compute-bound,
    maxpool/upsample/bnstats memory-bound."""
    bounds = {}
    for name, f in ps.ALL_KERNELS.items():
        op, _, _ = f()
        bounds[name] = op.bound
    assert bounds["ethash_like"] == "memory"
    assert bounds["maxpool"] == "memory"
    assert bounds["upsample"] == "memory"
    assert bounds["bnstats"] == "memory"
    assert bounds["im2col"] == "memory"
    assert bounds["sha_like"] == "compute"
    assert bounds["blake_like"] == "compute"
    assert bounds["blake2b_like"] == "compute"
