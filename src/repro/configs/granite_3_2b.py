"""Granite-3.0-2B — dense GQA transformer [hf:ibm-granite/granite-3.0-2b-base]

40 layers, d_model 2048, 32 heads (GQA kv=8), d_ff 8192, vocab 49155,
SwiGLU, RMSNorm, RoPE, tied embeddings.
"""
from repro.configs.base import ModelConfig, register


@register("granite-3-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="dense",
        num_layers=40,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=49_155,
        activation="silu",
        norm="rmsnorm",
        tie_embeddings=True,
        source="[hf:ibm-granite/granite-3.0-2b-base; hf] GQA",
    )
