"""RMSNorm Pallas kernel — memory-bound row normalization (one HBM round
trip), standalone and as a fusible OpSpec.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.op_spec import OpSpec, Operand


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * (1.0 + s_ref[...])).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            bm: int = 256, interpret: bool = False) -> jax.Array:
    """x: (R, d); scale: (d,) fp32."""
    R, d = x.shape
    bm = min(bm, R)
    assert R % bm == 0
    import functools
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda s: (s, 0)),
                  pl.BlockSpec((1, d), lambda s: (0, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        interpret=interpret,
    )(x, scale.reshape(1, d))


def rmsnorm_op(R: int, d: int, dtype=jnp.bfloat16, bm: int = 256,
               eps: float = 1e-6) -> OpSpec:
    assert R % bm == 0

    def body(step, x_ref, s_ref, o_ref):
        _rmsnorm_kernel(x_ref, s_ref, o_ref, eps=eps)

    itemsize = jnp.dtype(dtype).itemsize
    return OpSpec(
        name=f"rmsnorm_{R}x{d}", grid=R // bm, body=body,
        inputs=(Operand((R, d), dtype, (bm, d), lambda s: (s, 0)),
                Operand((1, d), jnp.float32, (1, d), lambda s: (0, 0))),
        outputs=(Operand((R, d), dtype, (bm, d), lambda s: (s, 0)),),
        flops=4.0 * R * d,
        hbm_bytes=2.0 * R * d * itemsize,
        tag="framework:rmsnorm",
        in_names=("x", "scale"), out_names=("out",))
