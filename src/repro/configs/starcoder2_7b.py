"""StarCoder2-7B — dense GQA code LM [arXiv:2402.19173; hf:bigcode/starcoder2-7b]

32 layers, d_model 4608, 36 heads (GQA kv=4), d_ff 18432, vocab 49152,
RoPE, gelu MLP (non-gated), LayerNorm.
"""
from repro.configs.base import ModelConfig, register


@register("starcoder2-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49_152,
        activation="gelu_mlp",
        norm="layernorm",
        rope_theta=100_000.0,
        source="[arXiv:2402.19173; hf] GQA + RoPE",
    )
