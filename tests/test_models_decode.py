"""Cache-correctness invariant: prefill(S) + decode_step == forward(S+1)
for every architecture (fp32), covering GQA/MLA-absorbed/ring-buffer/
RG-LRU/mLSTM/sLSTM cache paths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import lm


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch, rng):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = lm.init(cfg, rng)
    B, S = 2, 16
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision_stub":
        batch["pixel_embeds"] = jax.random.normal(
            rng, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio_stub":
        toks = jax.random.randint(rng, (B, cfg.num_codebooks, S + 1), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}

    logits_full, _aux, _mask = lm.forward(cfg, params, batch, remat=False)
    pb = dict(batch)
    pb["tokens"] = toks[:, :S] if cfg.frontend != "audio_stub" else toks[:, :, :S]
    pb.pop("labels")
    cache, pl_logits = lm.prefill(cfg, params, pb, max_len=S + 8)
    assert float(jnp.max(jnp.abs(pl_logits - logits_full[:, S - 1]))) < 1e-4

    tok_t = toks[:, S] if cfg.frontend != "audio_stub" else toks[:, :, S]
    dec_logits, cache2 = lm.decode_step(cfg, params, cache, tok_t)
    assert float(jnp.max(jnp.abs(dec_logits - logits_full[:, S]))) < 1e-4
    assert int(cache2["pos"]) == S + 1


def test_two_decode_steps_chain(rng):
    """Decode twice; position/cache threading stays consistent."""
    cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                              dtype="float32")
    params = lm.init(cfg, rng)
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S + 2), 0, cfg.vocab_size)
    logits_full, _, _ = lm.forward(
        cfg, params, {"tokens": toks, "labels": toks}, remat=False)
    cache, _ = lm.prefill(cfg, params, {"tokens": toks[:, :S]}, max_len=S + 4)
    d1, cache = lm.decode_step(cfg, params, cache, toks[:, S])
    d2, cache = lm.decode_step(cfg, params, cache, toks[:, S + 1])
    assert float(jnp.max(jnp.abs(d2 - logits_full[:, S + 1]))) < 1e-4
