"""Batched serving engine (iteration-level batching with refill).

Semantics: up to ``batch`` requests run in lock-step — prompts are
right-aligned/padded, prefilled with the batched ``lm.prefill``, then decoded
together; finished sequences are masked out and the batch refills at the next
wavefront.  Per-slot-position continuous batching would need a vectorized
cache position (B,) — noted as an extension in DESIGN.md; iteration-level
batching is what the assigned decode shapes (uniform context length) model.

Fusion execution (``plan_fusion=True``): the decode step is *planned* by
``plan_decode_fusion`` and *executed* through the plan->program executor
(core/executor) — the norm -> decode-attention -> FFN-projection chain runs
as Pallas kernels routed by a binding registry over the live wave state
(hidden activations, the KV-cache blocks, the layer weights), with the
model glue (QKV projection, RoPE, residuals, gating, head) living in the
binding setters.  When another wave is waiting, its prompt's FFN
in-projection — the compute-bound partner the planner pairs with the
memory-bound cache streaming — rides in the same fused launch, and the
rest of that wave's prefill completes in the same jitted step: chunked
prefill⊕decode co-execution, the dual-stream mode with *used* outputs.
Configs outside the supported shape (multi-run stacks, MoE, non-RMSNorm)
fall back to the hand-wired ``lm.decode_step`` with a notice.

On the production mesh the cache is sequence-sharded and decode attention is
the distributed flash-decode (DESIGN.md §7).  ``examples/dual_stream_decode.py``
shows the horizontal-fusion dual-stream variant of the decode step.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.models import lm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_token: Optional[int] = None
    out_tokens: list = field(default_factory=list)
    done: bool = False


def executable_decode_supported(cfg: ModelConfig) -> Optional[str]:
    """None when the planned decode program can replace ``lm.decode_step``
    for this config; otherwise the reason for the hand-wired fallback."""
    runs = lm.layer_runs(cfg)
    if cfg.frontend != "none":
        return f"frontend {cfg.frontend!r} (token frontend only)"
    if len(runs) != 1 or runs[0].count != 1 or runs[0].kind != ATTN:
        return "needs a single unstacked global-attention layer run"
    if cfg.is_moe:
        return "MoE FFN dispatch not yet routed through the executor"
    if cfg.norm != "rmsnorm":
        return f"norm {cfg.norm!r} (rmsnorm only)"
    if cfg.d_ff <= 0:
        return "no FFN"
    if cfg.activation not in ("silu", "gelu", "gelu_mlp", "relu2_mlp"):
        return f"activation {cfg.activation!r}"
    return None


def _ffn_in_width(cfg: ModelConfig) -> int:
    """Width of the decode step's FFN in-projection — the real ``w_in``
    (gated activations fuse gate+up into one (d, 2f) matmul)."""
    if cfg.moe is not None:
        return cfg.moe.num_experts
    if cfg.d_ff <= 0:
        return cfg.d_model
    return 2 * cfg.d_ff if cfg.activation in ("silu", "gelu") else cfg.d_ff


def _mlp_from_h(cfg: ModelConfig, h, w_out):
    """layers.mlp, minus the in-projection the executor already ran."""
    act = cfg.activation
    if act in ("silu", "gelu"):
        gate, up = jnp.split(h, 2, axis=-1)
        g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
        h = g * up
    elif act == "gelu_mlp":
        h = jax.nn.gelu(h)
    elif act == "relu2_mlp":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(act)
    return h @ w_out


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch: int = 8,
                 max_len: int = 512, rng_seed: int = 0,
                 plan_fusion: bool = False, measure=None,
                 schedule_cache=None):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.rng = jax.random.PRNGKey(rng_seed)
        self._measure = measure
        self._schedule_cache = schedule_cache
        self._decode = jax.jit(
            lambda p, c, t: lm.decode_step(cfg, p, c, t))
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(cfg, p, b, max_len=self.max_len))

        self.executed = False
        self._mixed_steps: dict[int, object] = {}   # prompt len -> jitted step
        self.fusion_plan = None
        if plan_fusion:
            reason = executable_decode_supported(cfg)
            if reason is None:
                # the executed decode program indexes the cache by the
                # planned (128-aligned) length — size the cache to match
                self.max_len = self._aligned_len()
                self._decode = jax.jit(self._make_decode_step(prefill_len=0))
                self.executed = True
            else:
                print(f"[plan-fusion] decode step stays hand-wired: {reason}")
            self.fusion_plan = self.plan_decode_fusion(
                measure=measure, cache=schedule_cache)

    # ------------------------------------------------------------------
    def _aligned_len(self) -> int:
        return max(128, -(-self.max_len // 128) * 128)

    def decode_graph(self, *, prefill_rows: int = 2048,
                     dynamic_length: bool = True):
        """The serving step as a planner graph, with stable operand
        signatures (core/binding.py): decode-wave RMSNorm -> decode
        attention -> post-attention RMSNorm -> the router/FFN in-projection,
        plus a prefill-chunk FFN matmul — the compute-bound partner of the
        chunked-prefill⊕decode overlap mode.  ``prefill_rows=0`` drops the
        prefill partner (a pure decode step: a dependency chain the planner
        correctly leaves unfused).
        """
        from repro.core import planner
        from repro.kernels.decode_attention import decode_attention_op
        from repro.kernels.matmul import matmul_1d_op
        from repro.kernels.rmsnorm import rmsnorm_op

        cfg = self.cfg
        d, H, Hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
        D = cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        S = self._aligned_len()                        # cache, 128-aligned
        B = self.batch

        norm1 = dataclasses.replace(rmsnorm_op(R=B, d=d, dtype=dt, bm=B),
                                    name="decode_norm1")
        norm2 = dataclasses.replace(rmsnorm_op(R=B, d=d, dtype=dt, bm=B),
                                    name="decode_norm2")
        # largest 128-multiple chunk <= 1024 that divides S (S is 128-aligned,
        # so the scan bottoms out at ck=128)
        ck = next(c for c in range(min(1024, S), 0, -128) if S % c == 0)
        att = decode_attention_op(B=B, S=S, H=H, Hkv=Hkv, D=D, dtype=dt,
                                  ck=ck, dynamic_length=dynamic_length)
        # decode-wave projection: MoE router when the model routes, else the
        # FFN in-projection — weight streaming dominates at serving batch
        # (memory-bound; the honest fig_framework finding), so the planner
        # pairs it with the prefill chunk's genuinely compute-bound matmul.
        proj = matmul_1d_op(M=B, K=d, N=_ffn_in_width(cfg), dtype=dt, bm=B)
        proj = dataclasses.replace(
            proj, name="moe_router" if cfg.moe is not None else "ffn_proj")
        # decode-step dataflow: norm1 -> attention -> norm2 -> router/FFN;
        # proj reads the POST-attention hidden state, so it can never fuse
        # with att — the only legal cross-stream partner is the prefill chunk
        graph = [planner.GraphOp(norm1),
                 planner.GraphOp(att, deps=frozenset({norm1.name})),
                 planner.GraphOp(norm2, deps=frozenset({norm1.name,
                                                        att.name})),
                 planner.GraphOp(proj, deps=frozenset({norm2.name}))]
        if prefill_rows:
            # the prefill chunk's partner is always a full-FFN-width matmul
            # (compute-bound at scale) — for MoE that is the expert FFN, not
            # the tiny router projection the decode side plans
            pf_n = (max(cfg.d_ff, d) if cfg.moe is not None
                    else _ffn_in_width(cfg))
            pf = matmul_1d_op(M=prefill_rows, K=d, N=pf_n,
                              dtype=dt, bm=min(128, prefill_rows))
            pf = dataclasses.replace(pf, name="prefill_ffn")
            graph.append(planner.GraphOp(pf))
        return graph

    def plan_decode_fusion(self, *, max_ways: int = 3, prefill_chunk: int = 2048,
                           measure=None, cache=None):
        """Register the serving step's ops as a planner graph (ROADMAP) and
        plan the bundles; ``build_decode_program`` lowers the result onto
        the live wave state.  With ``measure`` the schedule is profiled, and
        ``cache`` makes every later engine start skip the search entirely.
        """
        from repro.core import planner

        graph = self.decode_graph(prefill_rows=prefill_chunk)
        return planner.plan(graph, max_ways=max_ways, measure=measure,
                            cache=cache)

    # ------------------------------------------------------------------
    # Executed decode step: plan -> program -> live wave state
    # ------------------------------------------------------------------
    def build_decode_program(self, *, prefill_rows: int = 0,
                             interpret: Optional[bool] = None):
        """Compile the planned decode step into an executor Program bound to
        the live wave state.  The binding setters carry the model glue: the
        norm's output slot projects QKV, applies RoPE and writes the cache;
        the attention output slot applies W_o and the residual; the
        projection output slot finishes the MLP and the second residual.
        """
        from repro.core import executor, planner
        from repro.core.binding import BindingRegistry, Slot
        from repro.models import layers

        cfg = self.cfg
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        d, H, Hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
        D = cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        B = self.batch

        graph = self.decode_graph(prefill_rows=prefill_rows)
        # allow_same_bound: at full scale the prefill chunk is genuinely
        # compute-bound (the paper pairing); at smoke scale everything is
        # memory-bound and the launch/ramp amortization still decides —
        # admission stays the planner's, never forced
        plan = planner.plan(graph, max_ways=3, allow_same_bound=True,
                            measure=self._measure,
                            cache=self._schedule_cache)

        def norm1_put(state, y):
            x1 = y[:, None, :].astype(dt)                       # (B, 1, d)
            q, k, v = layers.qkv_project(cfg, {"w_qkv": state["w_qkv"]}, x1)
            positions = jnp.full((B, 1), state["pos"], jnp.int32)
            q = layers.rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
            k = layers.rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
            state = dict(state)
            state["q"] = q[:, 0]
            state["k_cache"] = jax.lax.dynamic_update_slice(
                state["k_cache"], k, (0, state["pos"], 0, 0))
            state["v_cache"] = jax.lax.dynamic_update_slice(
                state["v_cache"], v, (0, state["pos"], 0, 0))
            return state

        def att_put(state, o):
            attn_out = o.astype(dt).reshape(B, H * D) @ state["w_o"]
            state = dict(state)
            state["h_mid"] = state["x"] + attn_out              # residual 1
            return state

        def proj_put(state, h):
            ff = _mlp_from_h(cfg, h.astype(dt), state["w_out"])
            state = dict(state)
            state["x_out"] = state["h_mid"] + ff                # residual 2
            return state

        reg = BindingRegistry()
        reg.bind("decode_norm1", x="x", scale="norm1_scale",
                 outputs={"out": Slot(put=norm1_put)})
        att_name = next(g.op.name for g in graph
                        if g.op.name.startswith("decode_attn"))
        reg.bind(att_name, q="q", k="k_cache", v="v_cache",
                 inputs={"len": Slot(get=lambda s: (s["pos"] + 1)
                                     .reshape(1, 1).astype(jnp.int32))},
                 outputs={"o": Slot(put=att_put), "m": "attn_m",
                          "l": "attn_l"})
        reg.bind("decode_norm2", x="h_mid", scale="norm2_scale",
                 outputs={"out": "h2"})
        proj_name = "moe_router" if cfg.moe is not None else "ffn_proj"
        reg.bind(proj_name, x="h2", w="w_in",
                 outputs={"out": Slot(put=proj_put)})
        if prefill_rows:
            reg.bind("prefill_ffn", x="pf_h2", w="w_in", outputs={"out": "pf_ffn"})
        return executor.compile_plan(plan, bindings=reg, interpret=interpret)

    def _wave_state(self, params, cache, x):
        run = lm.layer_runs(self.cfg)[0]
        p = params[run.name]
        return {
            "x": x, "pos": cache["pos"],
            "norm1_scale": p["norm1"]["scale"].reshape(1, -1),
            "norm2_scale": p["norm2"]["scale"].reshape(1, -1),
            "w_qkv": p["attn"]["w_qkv"], "w_o": p["attn"]["w_o"],
            "w_in": p["mlp"]["w_in"], "w_out": p["mlp"]["w_out"],
            "k_cache": cache[run.name]["k"], "v_cache": cache[run.name]["v"],
        }

    def _make_decode_step(self, prefill_len: int):
        """The jitted executed decode step.  ``prefill_len > 0`` is the
        mixed form: the pending wave's (B, prefill_len) prompt rides along —
        its FFN in-projection joins the fused launch, the rest of its
        prefill completes here, and the returned (cache, logits) seed that
        wave's decode without ever calling ``lm.prefill``."""
        from repro.models import layers

        cfg = self.cfg
        B, d = self.batch, cfg.d_model
        run = lm.layer_runs(cfg)[0]
        S = self._aligned_len()
        P = prefill_len
        rows = B * P
        pf_rows = rows if rows <= 128 else -(-rows // 128) * 128
        program = self.build_decode_program(prefill_rows=pf_rows if P else 0)

        def step(params, cache, tokens, pf_tokens=None):
            p = params[run.name]
            x = layers.embed_onehot(params["embed"], tokens[:, None], d)
            state = self._wave_state(params, cache, x[:, 0])

            if P:
                # pending wave's prefill, up to the FFN in-projection
                xp, _ = lm._embed_inputs(cfg, params, {"tokens": pf_tokens})
                hp = layers.apply_norm(cfg, p["norm1"], xp)
                qp, kp, vp = layers.qkv_project(cfg, p["attn"], hp)
                positions = jnp.arange(P)[None, :]
                qp = layers.rope(qp, positions, cfg.rope_theta,
                                 cfg.rope_fraction)
                kp = layers.rope(kp, positions, cfg.rope_theta,
                                 cfg.rope_fraction)
                op_ = layers.blockwise_attention(qp, kp, vp, causal=True)
                xm = xp + op_.reshape(B, P, -1) @ p["attn"]["w_o"]
                h2p = layers.apply_norm(cfg, p["norm2"], xm)
                pf_x = h2p.reshape(rows, d)
                if pf_rows != rows:
                    pf_x = jnp.concatenate(
                        [pf_x, jnp.zeros((pf_rows - rows, d), pf_x.dtype)])
                state["pf_h2"] = pf_x.astype(jnp.dtype(cfg.dtype))

            state = program(state)

            xf = layers.apply_norm(cfg, params["final_norm"],
                                   state["x_out"][:, None, :].astype(x.dtype))
            logits = lm._head(cfg, params, xf)[:, 0]
            new_cache = {"pos": cache["pos"] + 1,
                         run.name: {"k": state["k_cache"],
                                    "v": state["v_cache"]}}
            if not P:
                return logits, new_cache

            ff = _mlp_from_h(cfg, state["pf_ffn"][:rows]
                             .astype(jnp.dtype(cfg.dtype)).reshape(B, P, -1),
                             p["mlp"]["w_out"])
            xop = xm + ff
            kc = jnp.zeros((B, S) + kp.shape[2:], kp.dtype)
            vc = jnp.zeros_like(kc)
            pf_cache = {"pos": jnp.asarray(P, jnp.int32),
                        run.name: {
                            "k": jax.lax.dynamic_update_slice(
                                kc, kp, (0, 0, 0, 0)),
                            "v": jax.lax.dynamic_update_slice(
                                vc, vp, (0, 0, 0, 0))}}
            xfp = layers.apply_norm(cfg, params["final_norm"], xop[:, -1:])
            pf_logits = lm._head(cfg, params, xfp)[:, 0]
            return logits, new_cache, pf_cache, pf_logits

        return step

    def _mixed_step(self, prefill_len: int):
        if prefill_len not in self._mixed_steps:
            self._mixed_steps[prefill_len] = jax.jit(
                self._make_decode_step(prefill_len))
        return self._mixed_steps[prefill_len]

    # ------------------------------------------------------------------
    def _wave_tokens(self, wave: list[Request]) -> np.ndarray:
        S = len(wave[0].prompt)
        toks = np.zeros((self.batch, S), np.int32)
        for i, r in enumerate(wave):
            toks[i] = r.prompt
        return toks

    def _prefill_wave(self, wave: list[Request]):
        """Waves are grouped by prompt length (see run()); empty slots
        duplicate row 0 and are ignored."""
        toks = self._wave_tokens(wave)
        cache, last_logits = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        return cache, last_logits

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        if req.temperature > 0:
            self.rng, sub = jax.random.split(self.rng)
            return int(jax.random.categorical(
                sub, jnp.asarray(logits) / req.temperature))
        return int(logits.argmax())

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        # group by prompt length: one wave = one (length, <=batch) group
        by_len: dict[int, list[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        pending: list[list[Request]] = []
        for _, group in sorted(by_len.items()):
            for i in range(0, len(group), self.batch):
                pending.append(group[i: i + self.batch])
        carried = None              # (cache, logits) co-prefilled for pending[0]
        while pending:
            wave = pending.pop(0)
            if carried is not None:
                cache, last_logits = carried
                carried = None
            else:
                cache, last_logits = self._prefill_wave(wave)
            logits = np.asarray(last_logits, np.float32)
            for i, r in enumerate(wave):
                r.out_tokens.append(self._sample(logits[i], r))
            budget = max(r.max_new_tokens for r in wave)
            for step_i in range(budget - 1):
                if all(r.done or len(r.out_tokens) >= r.max_new_tokens
                       for r in wave):
                    break
                toks = np.zeros((self.batch,), np.int32)
                for i, r in enumerate(wave):
                    toks[i] = r.out_tokens[-1]
                if (self.executed and step_i == 0 and pending
                        and carried is None):
                    # chunked prefill⊕decode co-execution: the next wave's
                    # prompt FFN rides in this step's fused launch
                    nxt = pending[0]
                    out, cache, pf_cache, pf_logits = self._mixed_step(
                        len(nxt[0].prompt))(
                            self.params, cache, jnp.asarray(toks),
                            jnp.asarray(self._wave_tokens(nxt)))
                    carried = (pf_cache, pf_logits)
                else:
                    out, cache = self._decode(self.params, cache,
                                              jnp.asarray(toks))
                logits = np.asarray(out, np.float32)
                for i, r in enumerate(wave):
                    if r.done or len(r.out_tokens) >= r.max_new_tokens:
                        continue
                    tok = self._sample(logits[i], r)
                    r.out_tokens.append(tok)
                    if r.eos_token is not None and tok == r.eos_token:
                        r.done = True
            for r in wave:
                r.done = True
        return requests
