"""OpSpec — the fusible-kernel IR of the horizontal-fusion engine.

An OpSpec is the TPU analogue of the paper's "input kernel": a computation
with a linear (1-D) grid of independent steps, per-operand BlockSpecs, and a
resource profile (FLOPs / HBM bytes / VMEM working set).  The paper's kernels
are CUDA source; ours are Pallas bodies.  The 1-D grid plays the role of the
block space; the *fused* kernel's grid (core/hfuse.py) partitions / interleaves
its steps between two ops the way HFUSE partitions the thread space.

Contract for ``body``:
  body(step, *in_refs, *out_refs) — ``step`` is the op-local grid step
  (a traced scalar); refs are VMEM blocks selected by the index maps.
  The body must not call pl.program_id itself (the fused kernel owns it).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.distributed.hlo_analysis import HBM_BW, PEAK_FLOPS, RIDGE, VMEM_BYTES


@dataclass(frozen=True)
class Operand:
    """One input or output of a fusible op."""
    shape: tuple[int, ...]
    dtype: Any
    block_shape: tuple[int, ...]
    index_map: Callable[[Any], tuple]      # op-local step -> block indices

    def block_bytes(self) -> int:
        return int(math.prod(self.block_shape)) * jnp.dtype(self.dtype).itemsize


@dataclass
class OpSpec:
    name: str
    grid: int                              # number of op-local steps
    body: Callable                         # body(step, *in_refs, *out_refs)
    inputs: tuple[Operand, ...]
    outputs: tuple[Operand, ...]
    flops: float                           # whole-op FLOPs
    hbm_bytes: float                       # whole-op HBM traffic (streaming)
    tag: str = ""                          # provenance (paper-suite name etc.)

    # ------------------------------------------------------------------
    @property
    def vmem_bytes(self) -> int:
        """Per-step working set (single-buffered)."""
        return sum(o.block_bytes() for o in (*self.inputs, *self.outputs))

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)

    @property
    def bound(self) -> str:
        """Roofline classification — the paper's 'kind of GPU resource'."""
        return "compute" if self.arithmetic_intensity >= RIDGE else "memory"

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_native(self) -> float:
        """Ideal pipelined standalone time: max of the two engine terms."""
        return max(self.t_compute, self.t_memory)

    def step_costs(self) -> tuple[float, float]:
        """(compute, memory) seconds per grid step (uniform-step assumption)."""
        return self.t_compute / self.grid, self.t_memory / self.grid

    def describe(self) -> dict:
        return {
            "name": self.name, "grid": self.grid, "flops": self.flops,
            "hbm_bytes": self.hbm_bytes, "vmem_bytes": self.vmem_bytes,
            "arithmetic_intensity": round(self.arithmetic_intensity, 2),
            "bound": self.bound,
            "t_compute_us": self.t_compute * 1e6,
            "t_memory_us": self.t_memory * 1e6,
            "t_native_us": self.t_native * 1e6,
        }


def make_operand(arr_or_sds, block_shape, index_map) -> Operand:
    return Operand(tuple(arr_or_sds.shape), arr_or_sds.dtype,
                   tuple(block_shape), index_map)
