"""Executed-plan report — speedups measured on the *actually executing*
program, not a side-channel microbenchmark.

  PYTHONPATH=src python -m benchmarks.executed [--backend interpret|device]

Two hot-path programs are planned, lowered by ``core/executor`` and run on
live operands:

  train_update — every param leaf's AdamW op (+ the dW matmul a 2-D
                 tensor's update depends on, with live activation/upstream-
                 grad operands routed through the binding registry: the
                 dep-forced dataflow the executor must order correctly).
  serve_decode — the ServeEngine mixed decode⊕prefill step: norm ->
                 decode attention (fused with the prefill chunk's FFN
                 in-projection) -> norm -> FFN projection over a live KV
                 cache.
  serve_continuous — the continuous-batching engine under a staggered
                 Poisson-ish arrival trace with a small PrefillBudget, so
                 prompts span 1-3 chunks: tokens/sec, slot occupancy, the
                 fraction of decode steps carrying a fused mixed
                 prefill⊕decode bundle (must be >= 80%: the steady mixed
                 graph, not wave-boundary-only), the fused-prefill fraction
                 and mean admission latency of the chunked admissions,
                 token-for-token verified against the legacy wavefront
                 engine, with a zero-new-searches replan over the shared
                 schedule cache.
  serve_stitched_vs_unstitched — the same mixed step planned with and
                 without epilogue stitching (core/stitch.py): the stitched
                 program must carry its producer→consumer chains as bundle
                 members, emit identical tokens, and beat the unstitched
                 program strictly on predicted HBM traffic and the
                 cost-model launch proxy.
  serve_paged_prefix — a shared-prefix trace served by the contiguous and
                 the paged (serve/kv_pool.py) executed engines: identical
                 tokens, STRICTLY fewer prefill chunks (the prefix cache
                 skips whole chunks), nonzero hit rate, and the block
                 table bound as a real operand on both paged attention
                 ops inside the fused launch.
  serve_sharded_vs_single — the same trace served single-device and
                 4-way tensor-parallel (shard_map over 4 fake CPU
                 devices, in a subprocess so XLA_FLAGS precedes the jax
                 import): identical token streams, a fused mixed
                 prefill⊕decode bundle in every shard's program, and
                 per-shard predicted HBM traffic strictly below the
                 single-device graph's.

Each program is verified against the hand-wired reference (jnp oracles /
``run_single`` chains / the wavefront differential oracle) and the
launch-level rows are wall-clocked against the native one-launch-per-op
baseline; the rows land in ``BENCH_executed_<backend>_<git-sha>.json``
(interpret timings are code-path exercise, not performance claims — the
numerics columns are the CI signal there).
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks.measured import git_sha


def _wall(fn, *args, repeats: int = 3) -> float:
    import jax
    jax.block_until_ready(fn(*args))          # compile + warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _train_update_row(interpret: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import executor, hfuse, planner
    from repro.core.binding import BindingRegistry
    from repro.kernels.adam import adamw_op
    from repro.kernels.matmul import matmul_1d_op

    # a 2-D tensor whose update depends on its own dW (leftover-forcing
    # dep) + a second tensor's update that CAN fuse with that dW
    M, K, N = 128, 64, 128
    dw = dataclasses.replace(
        matmul_1d_op(M=M, K=K, N=N, dtype=jnp.float32, bm=64),
        name="dW_w", tag="train:dW")
    upd_w = adamw_op(R=M, dtype=jnp.float32, bm=64, name="adamw_w")
    upd_b = adamw_op(R=256, dtype=jnp.float32, bm=64, name="adamw_b")
    graph = [planner.GraphOp(dw),
             planner.GraphOp(upd_w, deps=frozenset({"dW_w"})),
             planner.GraphOp(upd_b)]
    plan = planner.plan(graph, max_ways=3, allow_same_bound=True)

    reg = BindingRegistry()
    reg.bind("dW_w", x="x_act", w="g_up", out="w.g")
    reg.bind("adamw_w", scalars="scalars", p="w.p", g="w.g", m="w.m", v="w.v")
    reg.bind("adamw_b", scalars="scalars", p="b.p", g="b.g", m="b.m", v="b.v")
    prog = executor.compile_plan(plan, bindings=reg, interpret=interpret)

    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    sc = (jnp.zeros((1, 128), jnp.float32)
          .at[0, 0].set(1e-3).at[0, 1].set(0.1).at[0, 2].set(0.05))
    state = {
        "scalars": sc,
        "x_act": jax.random.normal(ks[0], (M, K)),
        "g_up": jax.random.normal(ks[1], (K, N)) * 0.1,
        "w.p": jax.random.normal(ks[2], (M, 128)),
        "w.m": jnp.zeros((M, 128)), "w.v": jnp.zeros((M, 128)),
        "b.p": jax.random.normal(ks[3], (256, 128)),
        "b.g": jax.random.normal(ks[4], (256, 128)) * 0.01,
        "b.m": jnp.zeros((256, 128)), "b.v": jnp.zeros((256, 128)),
    }
    run = jax.jit(prog)
    out = run(state)

    # hand-wired reference: jnp dataflow
    g_ref = state["x_act"] @ state["g_up"]
    m2 = 0.1 * g_ref
    v2 = 0.05 * g_ref * g_ref
    p_ref = state["w.p"] - 1e-3 * (
        (m2 / 0.1) / (jnp.sqrt(v2 / 0.05) + 1e-8) + 0.1 * state["w.p"])
    err = float(np.max(np.abs(np.asarray(out["w.p"]) - np.asarray(p_ref))))

    # native baseline: one launch per graph op, dep order
    singles = {g.op.name: hfuse.run_single(g.op, interpret=interpret)
               for g in graph}

    def native(state):
        state = dict(state)
        (state["w.g"],) = singles["dW_w"](state["x_act"], state["g_up"])
        for t in ("w", "b"):
            p, m, v = singles[f"adamw_{t}"](
                state["scalars"], state[f"{t}.p"], state[f"{t}.g"],
                state[f"{t}.m"], state[f"{t}.v"])
            state[f"{t}.p"], state[f"{t}.m"], state[f"{t}.v"] = p, m, v
        return state

    return {
        "program": "train_update",
        "fused_launches": prog.n_fused,
        "total_launches": len(prog.steps),
        "native_launches": len(graph),
        "steps": prog.describe(),
        "max_err": err,
        "executed_s": _wall(run, state),
        "native_s": _wall(jax.jit(native), state),
    }


def _serve_decode_row(interpret: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import ServeEngine

    cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                              dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=2, max_len=48, plan_fusion=True)
    assert eng.executed, "reduced granite must support the executed decode"

    P = 12
    toks = jnp.stack([jnp.arange(1, 1 + P, dtype=jnp.int32),
                      jnp.arange(3, 3 + P, dtype=jnp.int32)])
    cache, logits = lm.prefill(cfg, params, {"tokens": toks},
                               max_len=eng.cache_len)
    cur = jnp.argmax(logits, -1)
    mixed = eng._mixed_step(P)

    out_exe, _, _, pf_logits = mixed(params, cache, cur, toks)
    out_ref, _ = lm.decode_step(cfg, params, cache, cur)
    err = float(np.max(np.abs(np.asarray(out_exe) - np.asarray(out_ref))))
    # the co-prefilled wave must agree with a hand-wired lm.prefill
    _, ref_logits = lm.prefill(cfg, params, {"tokens": toks},
                               max_len=eng.cache_len)
    err_pf = float(np.max(np.abs(np.asarray(pf_logits)
                                 - np.asarray(ref_logits))))

    prog = eng.build_decode_program(ffn_rows=128)
    native = jax.jit(lambda p, c, t: lm.decode_step(cfg, p, c, t))
    return {
        "program": "serve_decode_mixed",
        "fused_launches": prog.n_fused,
        "total_launches": len(prog.steps),
        "steps": prog.describe(),
        "max_err": err,
        "max_err_coprefill": err_pf,
        "executed_s": _wall(mixed, params, cache, cur, toks),
        "native_decode_plus_prefill_s": (
            _wall(native, params, cache, cur)
            + _wall(jax.jit(lambda p, b: lm.prefill(cfg, p, b,
                                                    max_len=eng.cache_len)),
                    params, {"tokens": toks})),
    }


def _serve_continuous_row(interpret: bool) -> dict:
    import tempfile
    import time as _time

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import autotuner
    from repro.core.schedule_cache import ScheduleCache
    from repro.models import lm
    from repro.serve.engine import PrefillBudget, Request, ServeEngine

    cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                              dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    # small chunk budget so prompts span 1-3 chunks and two prefilling
    # slots' chunks co-reside with decode attention in one fused launch
    budget = PrefillBudget(chunk_rows=8, max_coresident_chunks=2)

    def make_requests():
        # staggered lengths + short decorrelated budgets + Poisson-ish
        # arrivals: slots retire every 1-2 steps, so nearly every decode
        # iteration carries a prefill chunk (the steady mixed graph);
        # every third prompt exceeds the chunk budget and is admitted
        # across multiple iterations
        rng = np.random.default_rng(7)
        arrive = 0.0
        reqs = []
        for i in range(24):
            arrive += rng.exponential(0.3)
            reqs.append(Request(
                rid=i,
                prompt=rng.integers(1, cfg.vocab_size,
                                    (8, 12, 20)[i % 3]).astype(np.int32),
                max_new_tokens=int(rng.integers(2, 4)),
                arrival=int(arrive)))
        return reqs

    with tempfile.TemporaryDirectory() as td:
        sched = ScheduleCache(Path(td) / "sched.json")
        eng = ServeEngine(cfg, params, batch=3, max_len=64, plan_fusion=True,
                          scheduling="continuous", schedule_cache=sched,
                          prefill_budget=budget)
        assert eng.executed, "reduced granite must support the executed decode"
        reqs = make_requests()
        t0 = _time.perf_counter()
        eng.run(reqs)
        dt = _time.perf_counter() - t0
        st = eng.stats

        # differential oracle: the legacy wavefront engine on the same set
        ref = make_requests()
        ServeEngine(cfg, params, batch=3, max_len=64,
                    scheduling="wavefront").run(ref)
        mismatch = sum(a.out_tokens != b.out_tokens
                       for a, b in zip(reqs, ref))

        # replan over the shared cache: zero new autotuner searches
        n = autotuner.SEARCH_COUNT
        eng2 = ServeEngine(cfg, params, batch=3, max_len=64,
                           plan_fusion=True, scheduling="continuous",
                           schedule_cache=sched, prefill_budget=budget)
        eng2.run(make_requests())
        new_searches = autotuner.SEARCH_COUNT - n

    # the launch table of one of the mixed programs that actually ran
    mixed_infos = [info for p, info in eng.cb_program_info.items() if p]
    assert mixed_infos, \
        "arrival trace never compiled an executed mixed (refill) program"
    return {
        "program": "serve_continuous",
        **mixed_infos[0],
        "token_mismatches": int(mismatch),   # vs the wavefront oracle
        "executed_s": dt,
        "tokens_per_s": st.tokens / max(dt, 1e-9),
        "slot_occupancy": st.occupancy,
        "mixed_step_fraction": st.mixed_fraction,
        "fused_mixed_fraction": st.fused_mixed_steps / max(st.decode_steps,
                                                           1),
        "fused_mixed_steps": st.fused_mixed_steps,
        "decode_steps": st.decode_steps,
        "prefill_chunks": st.prefill_chunks,
        "fused_prefill_fraction": st.fused_prefill_fraction,
        "mean_admission_latency_steps": st.mean_admission_latency,
        "replan_new_searches": int(new_searches),
        "slot_trace": st.describe(),
    }


def _serve_stitched_row(interpret: bool) -> dict:
    """Epilogue stitching (core/stitch.py) as a perf delta: the same mixed
    decode⊕prefill step planned twice — once with the decode graph's
    producer→consumer pairs stitched into chain members, once with the
    pairs as separate ops — and compared on the planner's own deterministic
    books: predicted HBM traffic (the stitched program never round-trips
    the normed hidden state or the pre-activation FFN block) and the
    cost-model launch proxy (``cost_model.native_time`` summed over the
    program's ops).  Token streams are verified identical, so the delta is
    pure traffic/launch accounting, not a numerics trade."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.cost_model import native_time
    from repro.core.stitch import CHAIN_SEP
    from repro.models import lm
    from repro.serve.engine import PrefillBudget, Request, ServeEngine

    cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                              dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    budget = PrefillBudget(chunk_rows=8, max_coresident_chunks=2)

    def requests():
        rng = np.random.default_rng(11)
        return [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab_size, L)
                        .astype(np.int32), max_new_tokens=m)
                for i, (L, m) in enumerate(zip((6, 15, 41), (3, 4, 3)))]

    progs, streams = {}, {}
    for label, stitched in (("stitched", True), ("unstitched", False)):
        eng = ServeEngine(cfg, params, batch=2, max_len=48,
                          scheduling="continuous", plan_fusion=True,
                          prefill_budget=budget, stitch_epilogues=stitched)
        assert eng.executed
        progs[label] = eng.build_decode_program(prefill_chunks=2)
        rs = requests()
        eng.run(rs)
        streams[label] = [r.out_tokens for r in rs]
    assert streams["stitched"] == streams["unstitched"], \
        "stitching changed the token stream"

    def books(prog):
        ops = [g.op for g in prog.graph]
        return (sum(op.hbm_bytes for op in ops),
                sum(native_time(op) for op in ops))

    hbm_s, t_s = books(progs["stitched"])
    hbm_u, t_u = books(progs["unstitched"])
    chains = [g.op.name for g in progs["stitched"].graph
              if CHAIN_SEP in g.op.name]
    return {
        "program": "serve_stitched_vs_unstitched",
        "fused_launches": progs["stitched"].n_fused,
        "total_launches": len(progs["stitched"].steps),
        "unstitched_launches": len(progs["unstitched"].steps),
        "stitched_chains": chains,
        "steps": progs["stitched"].describe(),
        "token_mismatches": 0,            # asserted identical above
        "predicted_hbm_bytes_stitched": hbm_s,
        "predicted_hbm_bytes_unstitched": hbm_u,
        "proxy_time_stitched_s": t_s,
        "proxy_time_unstitched_s": t_u,
    }


def _serve_paged_row(interpret: bool) -> dict:
    """Paged KV + prefix caching (serve/kv_pool.py) as a measured delta:
    the same shared-prefix trace served by the contiguous and the paged
    executed engines.  Gates: token streams identical (the block-table
    indirection is pure data movement), the paged run admits STRICTLY
    fewer prefill chunks (the prefix cache skips whole chunks of the
    shared prompt prefix), the hit rate is nonzero, and the fused decode
    launch really carries the block table on both paged attention ops."""
    import time as _time

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import PrefillBudget, Request, ServeEngine

    cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                              dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    # chunk_rows=16 -> effective chunk 16 on BOTH paths (the paged chunk
    # must be a kv-block multiple), so chunk counts compare directly
    budget = PrefillBudget(chunk_rows=16, max_coresident_chunks=2)

    def requests():
        rng = np.random.default_rng(13)
        shared = rng.integers(1, cfg.vocab_size, 32).astype(np.int32)
        return [Request(rid=i,
                        prompt=np.concatenate([
                            shared, rng.integers(1, cfg.vocab_size, L)
                            .astype(np.int32)]),
                        max_new_tokens=m)
                for i, (L, m) in enumerate(zip((7, 9, 5, 11), (3, 3, 3, 3)))]

    kw = dict(batch=2, max_len=64, scheduling="continuous",
              plan_fusion=True, prefill_budget=budget)
    contig = ServeEngine(cfg, params, **kw)
    paged = ServeEngine(cfg, params, **kw, paged_kv=True, kv_block_size=16)
    assert contig.executed and paged.executed
    rc, rp = requests(), requests()
    contig.run(rc)
    t0 = _time.perf_counter()
    paged.run(rp)
    dt = _time.perf_counter() - t0
    mismatch = sum(a.out_tokens != b.out_tokens for a, b in zip(rp, rc))

    graph = paged.decode_graph(prefill_chunks=1)
    paged_ops = [g.op for g in graph
                 if g.op.name.startswith(("decode_attn", "prefill_attn"))]
    bt_bound = all("bt" in op.in_names and op.name.endswith("_pg16")
                   for op in paged_ops)
    prog = paged.build_decode_program(prefill_chunks=1)
    chunk_fused = any(
        any(m.startswith("prefill_attn") for m in ms)
        and any(not m.startswith("prefill_attn") for m in ms)
        for ms in prog.fused_members)
    st = paged.stats
    return {
        "program": "serve_paged_prefix",
        "fused_launches": prog.n_fused,
        "total_launches": len(prog.steps),
        "steps": prog.describe(),
        "token_mismatches": int(mismatch),   # vs the contiguous engine
        "executed_s": dt,
        "paged_prefill_chunks": st.prefill_chunks,
        "contiguous_prefill_chunks": contig.stats.prefill_chunks,
        "prefix_hits": st.prefix_hits,
        "prefix_hit_rate": st.prefix_hit_rate,
        "prefix_tokens_reused": st.prefix_tokens_reused,
        "peak_blocks_in_use": st.blocks_in_use,
        "evictions": st.evictions,
        "block_table_bound": bool(bt_bound),
        "paged_chunk_fused": bool(chunk_fused),
        "pool": paged.kv_pool.snapshot(),
    }


def _serve_sharded_row(interpret: bool) -> dict:
    """Tensor-parallel serve as a measured delta: the same staggered trace
    served by the single-device executed engine and the 4-way shard_map
    engine (4 fake CPU devices).  Gates: token streams identical (the
    head-sharded attention + psum glue is pure partitioning), the shard
    program still carries a fused mixed prefill⊕decode bundle (SPMD traces
    one program per shard, so the engine's launch table IS every shard's),
    and the per-shard predicted HBM traffic — summed over the shard-local
    planner graph — is STRICTLY below the single-device graph's.

    Multi-device XLA_FLAGS must precede the jax import, so the comparison
    runs in a subprocess and reports its row as JSON."""
    import os
    import subprocess
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, {src!r})
        import dataclasses, json, time
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.models import lm
        from repro.serve.engine import PrefillBudget, Request, ServeEngine

        cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                                  dtype="float32")
        params = lm.init(cfg, jax.random.PRNGKey(0))
        budget = PrefillBudget(chunk_rows=8, max_coresident_chunks=2)

        def requests():
            rng = np.random.default_rng(17)
            return [Request(rid=i, prompt=rng.integers(
                                1, cfg.vocab_size, L).astype(np.int32),
                            max_new_tokens=m)
                    for i, (L, m) in enumerate(zip((6, 11, 7, 9, 8),
                                                   (4, 6, 5, 2, 3)))]

        kw = dict(batch=2, max_len=48, scheduling="continuous",
                  plan_fusion=True, prefill_budget=budget)
        single = ServeEngine(cfg, params, **kw)
        rs = requests()
        t0 = time.perf_counter(); single.run(rs)
        dt_single = time.perf_counter() - t0

        mesh = Mesh(np.array(jax.devices())[:4], ("model",))
        tp = ServeEngine(cfg, params, mesh=mesh, **kw)
        assert tp.tp_shards == 4 and tp.executed
        rt = requests()
        t0 = time.perf_counter(); tp.run(rt)
        dt_tp = time.perf_counter() - t0

        n = budget.max_coresident_chunks
        shard_hbm = sum(g.op.hbm_bytes
                        for g in tp.decode_graph(prefill_chunks=n))
        full_hbm = sum(g.op.hbm_bytes
                       for g in single.decode_graph(prefill_chunks=n))
        mixed = {{k: v for k, v in tp.cb_program_info.items() if k}}
        st = tp.stats
        row = {{"program": "serve_sharded_vs_single",
               **mixed[max(mixed)],
               "token_mismatches": int(sum(a.out_tokens != b.out_tokens
                                           for a, b in zip(rs, rt))),
               "tp_shards": tp.tp_shards, "mesh_tag": tp._mesh_tag,
               "executed_s": dt_tp, "single_device_s": dt_single,
               "per_shard_hbm_bytes": shard_hbm,
               "single_device_hbm_bytes": full_hbm,
               "mixed_chunks_fused": sorted(tp._cb_fused_chunks[max(mixed)]),
               "fused_mixed_steps": st.fused_mixed_steps,
               "fused_mixed_fraction": st.fused_mixed_steps
                                       / max(st.decode_steps, 1),
               "tokens": st.tokens, "slot_occupancy": st.occupancy}}
        print("SHARDED_ROW::" + json.dumps(row))
    """).format(src=str(Path(__file__).resolve().parents[1] / "src"))
    env = {**os.environ, "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    line = next(l for l in out.stdout.splitlines()
                if l.startswith("SHARDED_ROW::"))
    return json.loads(line[len("SHARDED_ROW::"):])


def _serve_moe_row(interpret: bool) -> dict:
    """MoE on the executed continuous-batching path: the router projection
    and the grouped expert GMM run as planner ops, with the GMM's expert
    weight streaming co-resident in a fused launch alongside a prefill
    chunk's attention — the paper's memory⊕compute pairing at the op the
    framework study calls its clearest instance.  Trace-driven in the
    NeuPIMs/DynaNDE harness shape: Poisson-ish arrivals, staggered prompt
    lengths, expert-load-aware ("eload") admission, and a vmapped-fallback
    differential oracle gating token-for-token parity."""
    import tempfile
    import time as _time

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import autotuner
    from repro.core.schedule_cache import ScheduleCache
    from repro.models import lm
    from repro.serve.engine import PrefillBudget, Request, ServeEngine

    cfg = dataclasses.replace(get_config("phi3.5-moe-rms").reduced(),
                              dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    budget = PrefillBudget(chunk_rows=8, max_coresident_chunks=2,
                           policy="eload")

    def make_requests():
        rng = np.random.default_rng(7)
        arrive = 0.0
        reqs = []
        for i in range(24):
            arrive += rng.exponential(0.3)
            reqs.append(Request(
                rid=i,
                prompt=rng.integers(1, cfg.vocab_size,
                                    (8, 12, 20)[i % 3]).astype(np.int32),
                max_new_tokens=int(rng.integers(2, 4)),
                arrival=int(arrive)))
        return reqs

    with tempfile.TemporaryDirectory() as td:
        sched = ScheduleCache(Path(td) / "sched.json")
        eng = ServeEngine(cfg, params, batch=3, max_len=64, plan_fusion=True,
                          scheduling="continuous", schedule_cache=sched,
                          prefill_budget=budget)
        assert eng.executed, \
            "reduced phi3.5-moe-rms must support the executed decode"
        reqs = make_requests()
        t0 = _time.perf_counter()
        eng.run(reqs)
        dt = _time.perf_counter() - t0
        st = eng.stats

        # differential oracle: the hand-wired vmapped fallback (plain
        # continuous, plan_fusion off) on the same trace
        ref = make_requests()
        ServeEngine(cfg, params, batch=3, max_len=64,
                    scheduling="continuous",
                    prefill_budget=budget).run(ref)
        mismatch = sum(a.out_tokens != b.out_tokens
                       for a, b in zip(reqs, ref))

        # replan over the shared cache: zero new autotuner searches
        n = autotuner.SEARCH_COUNT
        eng2 = ServeEngine(cfg, params, batch=3, max_len=64,
                           plan_fusion=True, scheduling="continuous",
                           schedule_cache=sched, prefill_budget=budget)
        eng2.run(make_requests())
        new_searches = autotuner.SEARCH_COUNT - n

    mixed_infos = [info for p, info in eng.cb_program_info.items() if p]
    assert mixed_infos, \
        "arrival trace never compiled an executed mixed (refill) program"
    gmm_fused = any(
        any(m.startswith("moe_gmm") for m in ms) and len(ms) > 1
        for info in mixed_infos for ms in info["fused_members"])
    return {
        "program": "serve_moe",
        **mixed_infos[0],
        "token_mismatches": int(mismatch),   # vs the vmapped fallback
        "moe_gmm_fused": bool(gmm_fused),
        "executed_s": dt,
        "tokens_per_s": st.tokens / max(dt, 1e-9),
        "slot_occupancy": st.occupancy,
        "fused_mixed_steps": st.fused_mixed_steps,
        "decode_steps": st.decode_steps,
        "expert_hits": list(st.expert_hits),
        "expert_skew": st.expert_skew,
        "load_shed_steps": st.load_shed_steps,
        "replan_new_searches": int(new_searches),
        "slot_trace": st.describe(),
    }


def run(backend: str = "interpret", out_path: str | None = None) -> dict:
    interpret = backend != "tpu" and backend != "gpu"
    rows = [_train_update_row(interpret), _serve_decode_row(interpret),
            _serve_continuous_row(interpret), _serve_stitched_row(interpret),
            _serve_paged_row(interpret), _serve_sharded_row(interpret),
            _serve_moe_row(interpret)]
    for r in rows:
        if "max_err" in r:
            assert r["max_err"] < 2e-4, (r["program"], r["max_err"])
        assert r.get("token_mismatches", 0) == 0, (
            r["program"], f"{r['token_mismatches']} streams diverged from "
            "the wavefront oracle")
        assert r["fused_launches"] >= 1, r["program"]
        err = (f"max_err {r['max_err']:.1e}" if "max_err" in r
               else f"{r['token_mismatches']} token mismatches")
        wall = (f", executed {r['executed_s'] * 1e3:.1f}ms"
                if "executed_s" in r else "")
        print(f"# executed {r['program']}: {r['fused_launches']} fused / "
              f"{r['total_launches']} launches, {err}{wall}")
    cont = rows[2]
    # gate the FUSED fraction: a refill only counts when its prefill chunk
    # verifiably shared a fused launch with decode attention
    assert cont["fused_mixed_fraction"] >= 0.8, (
        "continuous batching must keep the planner on a FUSED mixed "
        "prefill⊕decode bundle on >=80% of decode steps, got "
        f"{cont['fused_mixed_fraction']:.0%}")
    assert cont["replan_new_searches"] == 0, "replan re-searched a bundle"
    # chunked admission really fused: prefill chunks rode the decode launch
    assert cont["fused_prefill_fraction"] > 0.0, (
        "no prefill chunk ever shared a fused launch with decode attention")
    print(f"# continuous: {cont['tokens_per_s']:.1f} tok/s, occupancy "
          f"{cont['slot_occupancy']:.0%}, fused mixed bundle on "
          f"{cont['fused_mixed_fraction']:.0%} of decode steps, "
          f"{cont['fused_prefill_fraction']:.0%} of "
          f"{cont['prefill_chunks']} prefill chunks fused, admission "
          f"latency {cont['mean_admission_latency_steps']:.1f} steps")
    sv = rows[3]
    # epilogue stitching must be a STRICT win on the planner's own books:
    # less predicted HBM traffic and a lower launch/roofline proxy, with
    # (asserted above) bit-identical token streams
    assert sv["stitched_chains"], "decode program contains no stitched chain"
    assert (sv["predicted_hbm_bytes_stitched"]
            < sv["predicted_hbm_bytes_unstitched"]), sv
    assert sv["proxy_time_stitched_s"] < sv["proxy_time_unstitched_s"], sv
    saved = (1 - sv["predicted_hbm_bytes_stitched"]
             / sv["predicted_hbm_bytes_unstitched"])
    print(f"# stitched: {', '.join(sv['stitched_chains'])} — "
          f"{sv['total_launches']} launches vs "
          f"{sv['unstitched_launches']} unstitched, {saved:.1%} less "
          f"predicted HBM traffic, proxy "
          f"{sv['proxy_time_stitched_s'] * 1e6:.1f}us vs "
          f"{sv['proxy_time_unstitched_s'] * 1e6:.1f}us")
    pg = rows[4]
    # paged KV must be free on tokens and strictly cheaper on prefill:
    # the shared prefix's chunks are served from cached blocks, not re-run
    assert pg["block_table_bound"], \
        "paged attention op missing the bt operand"
    assert pg["paged_chunk_fused"], \
        "paged prefill chunk never shared a fused launch with decode work"
    assert pg["paged_prefill_chunks"] < pg["contiguous_prefill_chunks"], pg
    assert pg["prefix_hit_rate"] > 0, pg
    print(f"# paged: {pg['paged_prefill_chunks']} prefill chunks vs "
          f"{pg['contiguous_prefill_chunks']} contiguous "
          f"(prefix_hit_rate {pg['prefix_hit_rate']:.0%}, "
          f"{pg['prefix_tokens_reused']} tokens reused), peak "
          f"{pg['peak_blocks_in_use']} blocks, {pg['evictions']} evictions")
    sh = rows[5]
    # tensor parallelism must be free on tokens and a strict HBM win: the
    # shard-local graph streams 1/tp of the heads and FFN width while the
    # replicated norms stay whole, so per-shard traffic sits strictly
    # between full/tp and full
    assert sh["tp_shards"] == 4 and sh["mesh_tag"] == "model:4", sh
    assert sh["mixed_chunks_fused"], \
        "no prefill chunk fused into the shard program"
    assert sh["fused_mixed_steps"] >= 1, sh
    assert sh["per_shard_hbm_bytes"] < sh["single_device_hbm_bytes"], sh
    print(f"# sharded: {sh['tp_shards']}-way '{sh['mesh_tag']}', "
          f"{sh['fused_launches']} fused / {sh['total_launches']} launches "
          f"per shard, per-shard HBM "
          f"{sh['per_shard_hbm_bytes'] / sh['single_device_hbm_bytes']:.0%} "
          f"of single-device, fused mixed bundle on "
          f"{sh['fused_mixed_fraction']:.0%} of decode steps")
    moe = rows[6]
    # MoE serve gates: token-for-token with the vmapped fallback (asserted
    # above via token_mismatches == 0) AND the expert GMM verifiably
    # co-resident in a fused launch (Program.fused_members), with live
    # per-expert load stats feeding the eload admission policy
    assert moe["moe_gmm_fused"], (
        "the grouped expert GMM never shared a fused launch with a "
        "co-resident partner")
    assert moe["expert_hits"] and sum(moe["expert_hits"]) > 0, moe
    print(f"# moe: {moe['tokens_per_s']:.1f} tok/s, expert hits "
          f"{moe['expert_hits']} (skew {moe['expert_skew']:.2f}), "
          f"{moe['load_shed_steps']} load-shed steps, GMM fused "
          f"{moe['moe_gmm_fused']}")
    report = {"backend": backend, "git_sha": git_sha(), "rows": rows}
    out = Path(out_path or f"BENCH_executed_{backend}_{report['git_sha']}.json")
    out.write_text(json.dumps(report, indent=1))
    print(f"# wrote {out}")
    return report


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="interpret")
    args = ap.parse_args()
    run(args.backend)
