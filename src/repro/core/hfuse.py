"""Generate() — build the horizontally-fused Pallas kernel from N OpSpecs.

This is the TPU realization of the paper's Fig. 5 algorithm, generalized
from kernel *pairs* to N-op *bundles*:

  paper (CUDA thread space)             here (Pallas grid space)
  -------------------------------------------------------------------------
  threads [0,d1) run K1, [d1,d0) K2     grid steps interleave the bundle per
                                        the Schedule (r_0 : r_1 : ... : r_N)
  branch on threadIdx.x                 @pl.when(phase(program_id))
  replace threadIdx/blockDim with       op-local step s_i(t) passed to each
  tid_1/size_1, tid_2/size_2            body
  bar.sync id, d partial barriers       not needed: grid steps independent
                                        (see DESIGN.md §2)
  register cap (maxrregcount)           VMEM cap via block-shape choice +
                                        compiler vmem limit

DMA-elision scheduling: during any other op's phase, every operand's index
map *holds* its last value (Pallas skips the copy when the block index is
unchanged between steps).  Thus while a compute-bound member's step occupies
the MXU, the pipeline prefetches the memory-bound members' next blocks — the
warp-scheduler latency hiding of the paper, reconstructed with the only
latency-hiding machinery a TPU has.

The 2-op entry points (``generate(a, b, sched)``, ``generate_vfused(a, b)``,
``run_native(a, b)``) remain as thin wrappers over the bundle forms.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

from repro.core.cost_model import Schedule
from repro.core.op_spec import OpSpec


def _bundle_phase_fns(ops: Sequence[OpSpec], sched: Schedule):
    """Per-op (step, active) grid functions + total fused step count.

    Within a super-step of ``period`` fused steps, op i owns the phase
    window [off_i, off_i + r_i).  Outside its window its step index holds
    (clips to the last block it touched) so Pallas elides the DMAs.
    """
    period = sched.period
    offsets = sched.offsets()

    def make(i):
        r, off, grid = sched.ratios[i], offsets[i], ops[i].grid

        def step(t):
            s, ph = t // period, t % period
            p = ph - off
            # before my window: hold previous super-step's last block;
            # after it: hold this super-step's last block
            idx = s * r + jnp.clip(p, -1, r - 1)
            return jnp.clip(idx, 0, grid - 1)

        def active(t):
            s, ph = t // period, t % period
            p = ph - off
            return (p >= 0) & (p < r) & (s * r + p < grid)

        return step, active

    fns = [make(i) for i in range(len(ops))]
    n_super = max(math.ceil(op.grid / r)
                  for op, r in zip(ops, sched.ratios))
    return fns, n_super * period


def _normalize(ops, b, sched):
    """Accept generate(ops, sched) or the legacy generate(a, b, sched)."""
    if isinstance(ops, OpSpec):
        ops = (ops, b)
    else:
        ops, sched = tuple(ops), b if sched is None else sched
    if sched.n_ops != len(ops):
        raise ValueError(
            f"schedule has {sched.n_ops} ratios for {len(ops)} ops")
    return ops, sched


def generate(ops, b=None, sched: Optional[Schedule] = None, *,
             interpret: bool = False, vmem_limit: Optional[int] = None):
    """Returns fused(*op0_inputs, ..., *opN_inputs) ->
    (*op0_outputs, ..., *opN_outputs) — one Pallas call for the bundle."""
    ops, sched = _normalize(ops, b, sched)
    fns, n_steps = _bundle_phase_fns(ops, sched)

    n_ins = [len(op.inputs) for op in ops]
    n_outs = [len(op.outputs) for op in ops]
    in_off = [sum(n_ins[:i]) for i in range(len(ops) + 1)]
    out_off = [sum(n_outs[:i]) for i in range(len(ops) + 1)]
    n_in_total = in_off[-1]

    def fused_kernel(*refs):
        t = pl.program_id(0)
        for i, op in enumerate(ops):
            step, active = fns[i]
            ins = refs[in_off[i]:in_off[i + 1]]
            outs = refs[n_in_total + out_off[i]:n_in_total + out_off[i + 1]]

            @pl.when(active(t))
            def _(op=op, step=step, ins=ins, outs=outs):
                op.body(step(t), *ins, *outs)

    def remap(op_step, operand):
        return pl.BlockSpec(operand.block_shape,
                            lambda t, _f=operand.index_map, _s=op_step: _f(_s(t)))

    in_specs = [remap(fns[i][0], o)
                for i, op in enumerate(ops) for o in op.inputs]
    out_specs = [remap(fns[i][0], o)
                 for i, op in enumerate(ops) for o in op.outputs]
    out_shape = [jax.ShapeDtypeStruct(o.shape, o.dtype)
                 for op in ops for o in op.outputs]

    kwargs = {}
    if vmem_limit and not interpret and jax.default_backend() == "tpu":
        try:
            from jax.experimental.pallas import tpu as pltpu
            kwargs["compiler_params"] = pltpu.CompilerParams(
                vmem_limit_bytes=int(vmem_limit))
        except Exception:
            pass

    call = pl.pallas_call(
        fused_kernel,
        grid=(n_steps,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        **kwargs,
    )

    def fused(*operands):
        assert len(operands) == n_in_total, (len(operands), n_ins)
        outs = call(*operands)
        return tuple(outs) if isinstance(outs, (list, tuple)) else (outs,)

    fused.n_steps = n_steps
    fused.schedule = sched
    fused.ops = ops
    return fused


def generate_vfused(*ops, **kw):
    """Concatenated (vertical-style) baseline: all of op 0's steps, then all
    of op 1's, ... — one kernel, no interleaving.  Same machinery,
    degenerate schedule.  Accepts OpSpecs positionally or one sequence."""
    if len(ops) == 1 and not isinstance(ops[0], OpSpec):
        ops = tuple(ops[0])
    return generate(ops, Schedule(tuple(op.grid for op in ops)), **kw)


def run_single(op: OpSpec, *, interpret: bool = False):
    """Standalone pallas_call for one OpSpec (used by tests and `native`)."""
    def kernel(*refs):
        t = pl.program_id(0)
        op.body(t, *refs)

    call = pl.pallas_call(
        kernel,
        grid=(op.grid,),
        in_specs=[pl.BlockSpec(o.block_shape, o.index_map) for o in op.inputs],
        out_specs=[pl.BlockSpec(o.block_shape, o.index_map) for o in op.outputs],
        out_shape=[jax.ShapeDtypeStruct(o.shape, o.dtype) for o in op.outputs],
        interpret=interpret,
    )

    def run(*operands):
        outs = call(*operands)
        return tuple(outs) if isinstance(outs, (list, tuple)) else (outs,)
    return run


def run_native(*ops, interpret: bool = False):
    """The 'native' baseline: one pallas_call per op (N launches).

    NOTE: on a TPU core there is no stream concurrency — kernels
    serialize — which is why horizontal fusion is the *only* way N ops
    co-execute (DESIGN.md §8.5)."""
    if len(ops) == 1 and not isinstance(ops[0], OpSpec):
        ops = tuple(ops[0])
    calls = [run_single(op, interpret=interpret) for op in ops]

    def native(*operands):
        outs, off = [], 0
        for op, call in zip(ops, calls):
            outs.extend(call(*operands[off:off + len(op.inputs)]))
            off += len(op.inputs)
        return tuple(outs)

    return native
