"""Production mesh construction.

A *function*, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips / pod) single-pod, or 2x16x16 (512 chips) multi-pod.

    Axes: ('pod', 'data', 'model') multi-pod / ('data', 'model') single-pod.
    The 'pod' axis carries pure DP (or pipeline stages with --pp); 'model'
    is the fast intra-pod TP/EP/SP axis.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for tests (8 fake devices)."""
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
