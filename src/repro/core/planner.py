"""Graph-level fusion planner — decides WHICH independent ops to fuse.

The paper fuses kernel *pairs* that happen to be co-resident (different
CUDA streams, e.g. Batchnorm during training + Hist from a monitoring
pass).  In a framework we know the whole op graph, so the planner builds
N-way *bundles*:

  1. classifies every op by roofline bound (compute vs memory),
  2. builds the dependency closure (never fuse ops on a dependent path),
  3. seeds a bundle with the largest unused memory-bound op and its
     closest-native-time compute partner (the paper's Fig. 7: gains peak
     at execution-time ratio ~1),
  4. greedily grows the bundle up to ``max_ways`` members, admitting the
     op with the largest *marginal* predicted gain — an op only joins if
     co-scheduling it beats launching it natively (bin-packing by
     complementary roofline bound: the cost model only rewards members
     that ride the bundle's idle engine),
  5. runs the autotuner on each bundle and keeps those with predicted gain
     above a threshold — the paper's negative results (Blake256+SHA256
     loses) become planner rejections.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from repro.core import autotuner, hfuse, stitch
from repro.core.cost_model import native_time
from repro.core.op_spec import OpSpec
from repro.core.schedule_cache import ScheduleCache


@dataclass
class GraphOp:
    op: OpSpec
    deps: frozenset[str] = frozenset()       # names of ops this one reads from


@dataclass
class FusionDecision:
    members: tuple[str, ...]
    result: autotuner.SearchResult
    predicted_speedup_pct: float
    measured_speedup_pct: Optional[float] = None   # set when plan(measure=)


@dataclass
class FusionPlan:
    fused: list[FusionDecision]
    singles: list[str]
    rejected: list[tuple[str, str, str]]     # (members..., last, reason)
    graph: tuple["GraphOp", ...] = ()        # the graph this plan was built
    #                                          from (executor.compile_plan)

    def summary(self) -> list[dict]:
        """Uniform schema for every row — fused bundles and singles alike:
        members / schedule / vmem_cap / predicted_speedup_pct /
        measured_speedup_pct (None unless the plan ran with measure=)."""
        rows = [{
            "members": "+".join(d.members),
            "schedule": d.result.best.sched.label(),
            "vmem_cap": d.result.best.vmem_cap,
            "predicted_speedup_pct": round(d.predicted_speedup_pct, 1),
            "measured_speedup_pct": (None if d.measured_speedup_pct is None
                                     else round(d.measured_speedup_pct, 1)),
        } for d in self.fused]
        rows += [{"members": s, "schedule": "-", "vmem_cap": None,
                  "predicted_speedup_pct": 0.0, "measured_speedup_pct": None}
                 for s in self.singles]
        return rows


def _reachable(ops: dict[str, GraphOp]) -> dict[str, frozenset]:
    """Transitive dependency closure."""
    memo: dict[str, frozenset] = {}

    def visit(n: str) -> frozenset:
        if n in memo:
            return memo[n]
        acc = set(ops[n].deps)
        for d in ops[n].deps:
            if d in ops:
                acc |= visit(d)
        memo[n] = frozenset(acc)
        return memo[n]

    for n in ops:
        visit(n)
    return memo


def independent(ops: dict[str, GraphOp], a: str, b: str,
                clo: dict[str, frozenset] | None = None) -> bool:
    clo = clo if clo is not None else _reachable(ops)
    return b not in clo[a] and a not in clo[b]


def _independent_of_all(clo: dict[str, frozenset], bundle: Sequence[OpSpec],
                        cand: OpSpec) -> bool:
    return all(cand.name not in clo[m.name] and m.name not in clo[cand.name]
               for m in bundle)


def _contracted_acyclic(ops: dict[str, GraphOp],
                        bundles: Sequence[Sequence[str]]) -> bool:
    """True iff contracting each bundle to one super-node leaves the
    dependency graph acyclic — the executability contract
    ``executor._toposort`` enforces.  Pairwise independence of a bundle's
    members is NOT enough: a path a -> x -> b through an outside op turns
    the contracted {a, b} node into a cycle with x, and two bundles can
    feed each other through disjoint member pairs.  The planner checks
    every candidate grouping here so such bundles are never formed."""
    gid: dict[str, int] = {}
    for i, members in enumerate(bundles):
        for name in members:
            gid[name] = i
    n = len(bundles)
    for name in ops:
        if name not in gid:
            gid[name] = n
            n += 1
    edges: dict[int, set[int]] = {i: set() for i in range(n)}
    indeg = [0] * n
    for name, g in ops.items():
        for d in g.deps:
            if d in gid and gid[d] != gid[name] \
                    and gid[name] not in edges[gid[d]]:
                edges[gid[d]].add(gid[name])
                indeg[gid[name]] += 1
    ready = [i for i in range(n) if indeg[i] == 0]
    seen = 0
    while ready:
        seen += 1
        for w in edges[ready.pop()]:
            indeg[w] -= 1
            if indeg[w] == 0:
                ready.append(w)
    return seen == n


def _contract_chains(graph: Sequence[GraphOp]) -> tuple[GraphOp, ...]:
    """Contract declared epilogue chains (``OpSpec.epilogue``) into single
    stitched GraphOps — the vertical-fusion pre-pass that runs before any
    horizontal packing.

    A producer declaring ``epilogue=(consumer, operand)`` is contracted iff
      * the consumer exists and is the producer's ONLY reader (the
        intermediate really is dead after it — declaring the epilogue
        asserts no binding glue needs it either),
      * ``stitch.can_stitch`` accepts the pair (equal grids, per-step block
        correspondence, collision-free merged signature),
      * contracting it keeps the dependency graph acyclic (same
        ``_contracted_acyclic`` check bundles pass — a chain is a 2-bundle
        with a fixed internal order).
    A pair that fails any check is simply left unstitched: the plan is
    still valid, just without that vertical win.  Chains don't cascade
    (one level); each op joins at most one chain.  The contracted graph is
    what the rest of ``plan()`` sees — and what ``FusionPlan.graph``
    records, so ``executor.compile_plan`` binds the chain's external
    operands only."""
    ops = {g.op.name: g for g in graph}
    readers: dict[str, list[str]] = {n: [] for n in ops}
    for g in graph:
        for d in g.deps:
            if d in readers:
                readers[d].append(g.op.name)

    pairs: list[tuple[str, str]] = []
    taken: set[str] = set()
    for g in graph:
        if g.op.epilogue is None:
            continue
        pname = g.op.name
        cname, operand = g.op.epilogue
        if (cname not in ops or pname in taken or cname in taken
                or readers[pname] != [cname]
                or stitch.can_stitch(g.op, ops[cname].op, operand)
                is not None
                or not _contracted_acyclic(ops, pairs + [(pname, cname)])):
            continue
        pairs.append((pname, cname))
        taken |= {pname, cname}
    if not pairs:
        return tuple(graph)

    chainof: dict[str, str] = {}
    chain_at: dict[str, GraphOp] = {}
    for pname, cname in pairs:
        p, c = ops[pname], ops[cname]
        cop = stitch.stitch(p.op, c.op, p.op.epilogue[1])
        chainof[pname] = chainof[cname] = cop.name
        deps = (set(p.deps) | set(c.deps)) - {pname, cname}
        chain_at[pname] = GraphOp(cop, frozenset(deps))

    def mapdeps(ds: frozenset[str]) -> frozenset[str]:
        return frozenset(chainof.get(d, d) for d in ds)

    consumed = {c for _p, c in pairs}
    out: list[GraphOp] = []
    for g in graph:
        n = g.op.name
        if n in consumed:
            continue
        if n in chain_at:
            ch = chain_at[n]
            out.append(GraphOp(ch.op, mapdeps(ch.deps)))
        else:
            out.append(GraphOp(g.op, mapdeps(g.deps)))
    return tuple(out)


def _bundle_search(bundle: Sequence[OpSpec],
                   memo: dict[frozenset, autotuner.SearchResult],
                   cache: Optional[ScheduleCache],
                   mesh_tag: str = "") -> autotuner.SearchResult:
    """Autotune a bundle, memoized per bundle-name-set.

    Bundle growth re-evaluates every (bundle, candidate) pair each
    iteration — without the memo ``plan(max_ways>=3)`` is O(n^2) *full*
    searches.  Keyed by frozenset of member names: within one plan() call
    names are unique, so the name set identifies the OpSpec set."""
    key = frozenset(op.name for op in bundle)
    if key not in memo:
        memo[key] = autotuner.search(tuple(bundle), cache=cache,
                                     mesh_tag=mesh_tag)
    return memo[key]


def _bundle_cost(bundle: Sequence[OpSpec],
                 memo: dict[frozenset, autotuner.SearchResult],
                 cache: Optional[ScheduleCache],
                 mesh_tag: str = "") -> float:
    """Best predicted fused time for a bundle (cost-model autotune)."""
    return _bundle_search(bundle, memo, cache, mesh_tag).best.est.t_hfused


def _measured_speedup(res: autotuner.SearchResult, bundle: Sequence[OpSpec],
                      measure: Callable,
                      cache: Optional[ScheduleCache]) -> Optional[float]:
    """Profile the native baseline (N separate launches) against the tuned
    fused kernel — the measured analogue of FusedEstimate.speedup_pct.

    The native time rides in the bundle's cache entry (``native_s``), so a
    replanned graph pays zero profiling runs, not just zero searches."""
    if res.best.measured_s is None:
        return None
    entry = (cache.entries.get(res.cache_key)
             if cache is not None and res.cache_key else None)
    t_native = entry.get("native_s") if entry else None
    if t_native is None:
        native = hfuse.run_native(tuple(bundle))
        t_native = measure(native, *bundle)
        if entry is not None:
            entry["native_s"] = t_native
            cache.put(res.cache_key, entry)   # respects batched() deferral
    return 100.0 * (t_native - res.best.measured_s) / max(t_native, 1e-30)


def plan(graph: Sequence[GraphOp], *, min_gain_pct: float = 2.0,
         allow_same_bound: bool = False, max_ways: int = 2,
         measure: Optional[Callable] = None,
         cache: Optional[ScheduleCache] = None,
         mesh_tag: str = "") -> FusionPlan:
    """Build ≤``max_ways``-way fusion bundles over the independent ops.

    ``max_ways=2`` reproduces the paper's pairwise planning; raise it to
    let complementary ops pile into larger bundles when the cost model
    predicts a marginal win for each admission.

    ``measure``: profiling callable (core/timing.make_measure) — accepted
    bundles get their final schedule picked by measurement (the paper's
    Main() loop) and a measured_speedup_pct vs the profiled native
    baseline.  ``cache``: persistent ScheduleCache — every search consults
    it first, so re-planning an unchanged graph performs zero new searches.

    Declared epilogue chains (``OpSpec.epilogue``) are contracted into
    single stitched members first — ``_contract_chains`` — so horizontal
    packing runs over the vertically-fused graph.

    ``mesh_tag`` (``"<axis>:<extent>"``) marks a plan built over
    shard-local op shapes for one shard of a tensor-parallel mesh — it
    rides into every bundle signature so sharded and single-device plans
    never share schedule-cache entries.
    """
    graph = _contract_chains(graph)
    ops = {g.op.name: g for g in graph}
    memo: dict[frozenset, autotuner.SearchResult] = {}
    batch = cache.batched() if cache is not None else contextlib.nullcontext()
    with batch:
        return _plan_inner(graph, ops, memo, min_gain_pct, allow_same_bound,
                           max_ways, measure, cache, mesh_tag)


def _starves_unseeded(graph, ops, clo, used: set[str],
                      bundle: Sequence[OpSpec], x: OpSpec) -> bool:
    """True iff absorbing ``x`` into ``bundle`` would leave some not-yet-
    seeded memory-bound op with ZERO fusion partners.

    Greedy growth is launch-hungry: a bundle happily swallows every
    independent op whose native time it can amortize, even when a later
    seed needed that op as its only partner.  The canonical case is the
    serve decode graph with stitched chains: {decode_attn, chunk0} would
    absorb chunk1 too, leaving the FFN chain (dependent on decode_attn, so
    it can never join that bundle) alone — two launches where
    {att, chunk0} + {ffn_chain, chunk1} is the same launch count with the
    chain riding a fused launch.  The guard is purely structural (no cost
    queries): it only fires when the starved op's partner pool would hit
    zero, so homogeneous graphs (multi-tensor adamw piles, the paper
    triples) grow exactly as before."""
    names_now = {b.name for b in bundle}
    taken = used | names_now | {x.name}
    for g in graph:
        mp = g.op
        if mp.bound != "memory" or mp.name in taken:
            continue
        if _independent_of_all(clo, bundle, mp):
            continue                  # mp can still join this very bundle
        if not independent(ops, mp.name, x.name, clo):
            continue                  # x was never a partner for mp
        if not any(h.op.name not in taken and h.op.name != mp.name
                   and independent(ops, mp.name, h.op.name, clo)
                   for h in graph):
            return True
    return False


def _plan_inner(graph, ops, memo, min_gain_pct, allow_same_bound, max_ways,
                measure, cache, mesh_tag="") -> FusionPlan:
    clo = _reachable(ops)
    mem = sorted((g.op for g in graph if g.op.bound == "memory"),
                 key=lambda o: -o.t_native)
    comp = sorted((g.op for g in graph if g.op.bound == "compute"),
                  key=lambda o: -o.t_native)

    used: set[str] = set()
    fused: list[FusionDecision] = []
    accepted: list[tuple[str, ...]] = []     # member tuples, for the
    #                                          contracted-cycle guard
    rejected: list[tuple[str, str, str]] = []

    for m in mem:
        if m.name in used:
            continue
        # closest-native-time compute partner (paper: ratio ~1 is best);
        # the candidate pair must also keep the *contracted* graph acyclic
        partners = [c for c in comp if c.name not in used
                    and independent(ops, m.name, c.name, clo)
                    and _contracted_acyclic(ops,
                                            accepted + [(m.name, c.name)])]
        if not partners and allow_same_bound:
            partners = [c.op for c in graph
                        if c.op.name not in used and c.op.name != m.name
                        and independent(ops, m.name, c.op.name, clo)
                        and _contracted_acyclic(
                            ops, accepted + [(m.name, c.op.name)])]
        if not partners:
            continue
        c = min(partners, key=lambda o: abs(o.t_native - m.t_native))
        bundle = [m, c]

        # grow: admit the op with the largest marginal predicted gain —
        # t_hfused(bundle ∪ {x}) must beat t_hfused(bundle) + native(x)
        t_now = _bundle_cost(bundle, memo, cache, mesh_tag)
        while len(bundle) < max_ways:
            names_now = tuple(b.name for b in bundle)
            pool = [g.op for g in graph
                    if g.op.name not in used
                    and g.op.name not in names_now
                    and _independent_of_all(clo, bundle, g.op)
                    and _contracted_acyclic(
                        ops, accepted + [names_now + (g.op.name,)])
                    and not _starves_unseeded(graph, ops, clo, used,
                                              bundle, g.op)]
            if not pool:
                break
            scored = [(t_now + native_time(x)
                       - _bundle_cost(bundle + [x], memo, cache, mesh_tag), x)
                      for x in pool]
            marginal, x = max(scored, key=lambda s: s[0])
            # a material fraction of x's native time must vanish — launch-
            # overhead crumbs alone don't justify VMEM pressure (this is
            # what keeps same-bound ops out: they add to the busy engine)
            if marginal <= (min_gain_pct / 100.0) * native_time(x):
                break
            bundle.append(x)
            t_now = t_now + native_time(x) - marginal

        if measure is None:
            res = _bundle_search(bundle, memo, cache, mesh_tag)
        else:
            # measured final tuning (separate cache mode key: the measured
            # schedule may legitimately differ from the cost-model one)
            res = autotuner.search(tuple(bundle), measure=measure,
                                   cache=cache, mesh_tag=mesh_tag)
        gain = res.best.est.speedup_pct()
        names = tuple(b.name for b in bundle)
        measured_pct = (None if measure is None
                        else _measured_speedup(res, bundle, measure, cache))
        # measurement outranks the model for admission too: a bundle the
        # profiler shows losing is rejected no matter what the model says
        # (the paper's negative results, caught on hardware).  Rank-only
        # measures (the interpret CI proxy) pick schedules but their
        # absolute gains are launch-amortization noise — admission falls
        # back to the model's prediction for them.
        use_measured = (measured_pct is not None
                        and not getattr(measure, "rank_only", False))
        accept_gain = measured_pct if use_measured else gain
        if accept_gain >= min_gain_pct:
            fused.append(FusionDecision(names, res, gain, measured_pct))
            used |= set(names)
            accepted.append(names)
        else:
            kind = "measured" if use_measured else "predicted"
            rejected.append(("+".join(names[:-1]), names[-1],
                             f"{kind} gain {accept_gain:.1f}% "
                             f"< {min_gain_pct}%"))

    singles = [g.op.name for g in graph if g.op.name not in used]
    return FusionPlan(fused=fused, singles=singles, rejected=rejected,
                      graph=tuple(graph))
