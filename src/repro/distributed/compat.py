"""jax API-drift shims for the distributed layer.

``shard_map`` moved and changed its knob names across jax releases:

  jax >= 0.6           jax.shard_map(f, mesh=, in_specs=, out_specs=,
                                     axis_names=, check_vma=)
  jax 0.4.x - 0.5.x    jax.experimental.shard_map.shard_map(
                           f, mesh=, in_specs=, out_specs=,
                           check_rep=, auto=)

The two parameterizations are duals: new-style ``axis_names`` lists the
*manual* axes, old-style ``auto`` lists the non-manual remainder;
``check_vma`` renamed ``check_rep``.  Callers in this package use the
new-style vocabulary and this shim translates when running on an older
jax (the container pins 0.4.37).

Old-jax caveat: 0.4.x partial-auto shard_map cannot lower this package's
bodies (``axis_index`` hits the SPMD partitioner's PartitionId ambiguity;
``ppermute``/``psum`` trip an XLA ``IsManualSubgroup`` check), so the
fallback goes *fully manual* over every mesh axis instead.  Semantics are
preserved — specs that never mention the extra axes mean "replicated"
under both readings — but the region's interior loses automatic SPMD
partitioning over the non-manual axes (acceptable: these regions are
collective plumbing, not FLOP-heavy interiors).
"""
from __future__ import annotations

from typing import Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[set] = None, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        manual = frozenset(axis_names) if axis_names is not None \
            else frozenset(mesh.axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=manual,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
