"""Epilogue stitching (core/stitch.py) + planner chain contraction.

Parity contract: a stitched producer→consumer chain is BITWISE equal to
running the two kernels separately — including every shrink variant the
chain's shrink factory produces and the grid-1 degenerate — because the
producer's block value is captured *after* its final ``.astype`` and handed
to the consumer in-register.  Property-tested with hypothesis when it is
installed; otherwise the same check runs over a fixed seed sweep so the
contract is exercised everywhere.

Also here: the ``can_stitch`` rejection taxonomy, the row-stream reshape
case (dW (bm, N) blocks → adamw (bm·N/128, 128) blocks), planner
contraction legality (single reader, acyclicity, graceful fallback), chain
cost accounting, and the ScheduleCache regression — chain structure is part
of the bundle signature, so a stitched plan can never resolve an unstitched
plan's cached schedule.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hfuse, planner
from repro.core.op_spec import OpSpec, shrink_blocks
from repro.core.schedule_cache import ScheduleCache, bundle_signature
from repro.core.stitch import CHAIN_SEP, can_stitch, chain_label, stitch
from repro.kernels.adam import LANES, adamw_op
from repro.kernels.elementwise import (activation_op, residual_add_op,
                                       silu_gate)
from repro.kernels.matmul import matmul_1d_op
from repro.kernels.rmsnorm import rmsnorm_op

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _run(op, *args):
    return hfuse.run_single(op, interpret=True)(*args)


# ---------------------------------------------------------------------------
# Parity: chain == separate ops, bitwise
# ---------------------------------------------------------------------------
def _norm_matmul_parity(R, d, N, bm, factor, seed):
    """rmsnorm→matmul at block rows ``bm``, optionally shrunk by
    ``factor``, must match the separate pair bit for bit."""
    norm = rmsnorm_op(R=R, d=d, dtype=jnp.float32, bm=bm)
    mm = matmul_1d_op(M=R, K=d, N=N, dtype=jnp.float32, bm=bm)
    chain = stitch(norm, mm, "x")
    assert chain.name == chain_label(norm.name, mm.name)
    if factor > 1:
        chain = chain.shrink(factor)
        norm = shrink_blocks(norm, factor)
        mm = shrink_blocks(mm, factor)
        if chain is None or norm is None or mm is None:
            pytest.skip(f"factor {factor} unprovable at bm={bm}")
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(R, d)), jnp.float32)
    scale = jnp.asarray(rng.normal(size=(1, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, N)), jnp.float32)
    (y_sep,) = _run(norm, x, scale)
    (o_sep,) = _run(mm, y_sep, w)
    (o_chain,) = _run(chain, x, scale, w)
    assert np.array_equal(np.asarray(o_chain), np.asarray(o_sep)), \
        f"chain diverged at R={R} d={d} N={N} bm={bm} factor={factor}"


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(rows=st.sampled_from([16, 32, 64]),
           d=st.sampled_from([128, 256]),
           n=st.sampled_from([128, 384]),
           split=st.sampled_from([1, 2, 4]),
           factor=st.sampled_from([1, 2]),
           seed=st.integers(0, 2**31 - 1))
    def test_chain_parity_property(rows, d, n, split, factor, seed):
        bm = max(rows // split, 8)
        _norm_matmul_parity(rows, d, n, bm, factor, seed)
else:
    @pytest.mark.parametrize("rows,d,n,split,factor,seed", [
        (16, 128, 128, 1, 1, 0),       # grid-1 (whole array in one block)
        (32, 128, 384, 2, 1, 1),
        (32, 256, 128, 2, 2, 2),       # shrunk chain variant
        (64, 128, 128, 4, 1, 3),
        (64, 256, 384, 4, 2, 4),
        (64, 128, 384, 1, 2, 5),       # grid-1 shrunk into grid-2
    ])
    def test_chain_parity_property(rows, d, n, split, factor, seed):
        bm = max(rows // split, 8)
        _norm_matmul_parity(rows, d, n, bm, factor, seed)


def test_matmul_residual_add_chain_parity():
    R, K, N, bm = 32, 64, 128, 16
    mm = matmul_1d_op(M=R, K=K, N=N, dtype=jnp.float32, bm=bm)
    add = residual_add_op(R, N, dtype=jnp.float32, bm=bm)
    chain = stitch(mm, add, "h")
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(R, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    res = jnp.asarray(rng.normal(size=(R, N)), jnp.float32)
    (h,) = _run(mm, x, w)
    (o_sep,) = _run(add, h, res)
    (o_chain,) = _run(chain, x, w, res)
    assert np.array_equal(np.asarray(o_chain), np.asarray(o_sep))


def test_matmul_activation_chain_parity():
    R, K, F, bm = 32, 64, 128, 16
    mm = matmul_1d_op(M=R, K=K, N=2 * F, dtype=jnp.float32, bm=bm)
    act = activation_op(R, 2 * F, F, silu_gate, dtype=jnp.float32, bm=bm)
    chain = stitch(mm, act, "h")
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(R, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, 2 * F)), jnp.float32)
    (h,) = _run(mm, x, w)
    (o_sep,) = _run(act, h)
    (o_chain,) = _run(chain, x, w)
    assert np.array_equal(np.asarray(o_chain), np.asarray(o_sep))


def test_dw_adamw_reshape_chain_parity():
    """The row-stream case: dW's (bm, N) blocks feed adamw's (bm*N/128,
    128) blocks through a row-major reshape — same elements per step."""
    d_in, K, d_out, bmm = 32, 64, 256, 16
    rows = d_in * d_out // LANES                       # 64, no padding
    bm_i = bmm * d_out // LANES                        # 32 -> equal grids
    dw = matmul_1d_op(M=d_in, K=K, N=d_out, dtype=jnp.float32, bm=bmm)
    upd = adamw_op(R=rows, dtype=jnp.float32, bm=bm_i)
    chain = stitch(dw, upd, "g")
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(d_in, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, d_out)), jnp.float32)
    sc = (jnp.zeros((1, LANES), jnp.float32)
          .at[0, 0].set(1e-3).at[0, 1].set(0.1).at[0, 2].set(0.05))
    p = jnp.asarray(rng.normal(size=(rows, LANES)), jnp.float32)
    m = jnp.zeros((rows, LANES)), jnp.zeros((rows, LANES))
    m, v = m
    (g,) = _run(dw, x, w)
    sep = _run(upd, sc, p, g.reshape(rows, LANES), m, v)
    out = _run(chain, x, w, sc, p, m, v)
    for a, b in zip(out, sep):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# can_stitch rejection taxonomy
# ---------------------------------------------------------------------------
def test_can_stitch_rejections():
    norm = rmsnorm_op(R=32, d=128, dtype=jnp.float32, bm=16)
    mm = matmul_1d_op(M=32, K=128, N=128, dtype=jnp.float32, bm=16)
    assert can_stitch(norm, mm, "x") is None
    # grid mismatch
    mm8 = matmul_1d_op(M=32, K=128, N=128, dtype=jnp.float32, bm=8)
    assert "grid" in can_stitch(norm, mm8, "x")
    # dtype mismatch
    mmb = matmul_1d_op(M=32, K=128, N=128, dtype=jnp.bfloat16, bm=16)
    assert "dtype" in can_stitch(norm, mmb, "x")
    # unknown operand / wrong block shape for the named operand
    assert "no input named" in can_stitch(norm, mm, "nope")
    assert "block mismatch" in can_stitch(mm, mm, "x") or \
        can_stitch(mm, mm, "x") is not None
    # chains never cascade
    chain = stitch(norm, mm, "x")
    assert "cascade" in can_stitch(chain, mm, "x")
    # in-place consumer state can't be stitched
    upd = adamw_op(R=32, dtype=jnp.float32, bm=16)
    assert "in-place" in can_stitch(norm, upd, "p")
    # stitch() surfaces the reason
    with pytest.raises(ValueError, match="grid mismatch"):
        stitch(norm, mm8, "x")


def test_chain_cost_accounting():
    norm = rmsnorm_op(R=32, d=128, dtype=jnp.float32, bm=16)
    mm = matmul_1d_op(M=32, K=128, N=128, dtype=jnp.float32, bm=16)
    chain = stitch(norm, mm, "x")
    inter = 32 * 128 * 4                     # the eliminated intermediate
    assert chain.flops == norm.flops + mm.flops
    assert chain.hbm_bytes == norm.hbm_bytes + mm.hbm_bytes - 2 * inter
    # the live block rides VMEM instead
    assert chain.extra_vmem_bytes == norm.outputs[0].block_bytes()
    assert chain.vmem_bytes > mm.vmem_bytes
    assert chain.chain == (norm.name, mm.name)
    assert chain.in_names == ("x", "scale", "w")
    assert chain.out_names == ("out",)


# ---------------------------------------------------------------------------
# Planner contraction: graph-level legality
# ---------------------------------------------------------------------------
def _epilogue_graph(consumer_bm=16, extra_reader=False):
    norm = rmsnorm_op(R=32, d=128, dtype=jnp.float32, bm=16)
    mm = matmul_1d_op(M=32, K=128, N=128, dtype=jnp.float32, bm=consumer_bm)
    mm = dataclasses.replace(mm, name="mm")
    norm = dataclasses.replace(norm, name="norm",
                               epilogue=(mm.name, "x"))
    graph = [planner.GraphOp(norm),
             planner.GraphOp(mm, deps=frozenset({"norm"}))]
    if extra_reader:
        other = dataclasses.replace(
            rmsnorm_op(R=32, d=128, dtype=jnp.float32, bm=16), name="other")
        graph.append(planner.GraphOp(other, deps=frozenset({"norm"})))
    return graph


def test_planner_contracts_declared_epilogue():
    plan = planner.plan(_epilogue_graph(), max_ways=2)
    names = [m for d in plan.fused for m in d.members] + list(plan.singles)
    assert f"norm{CHAIN_SEP}mm" in names
    assert "norm" not in names and "mm" not in names


def test_planner_skips_contraction_with_second_reader():
    plan = planner.plan(_epilogue_graph(extra_reader=True), max_ways=2)
    names = [m for d in plan.fused for m in d.members] + list(plan.singles)
    assert "norm" in names and "mm" in names      # pair left unstitched
    assert not any(CHAIN_SEP in n for n in names)


def test_planner_falls_back_when_kernels_cannot_stitch():
    # grid mismatch: the declaration is advisory, the plan stays valid
    plan = planner.plan(_epilogue_graph(consumer_bm=8), max_ways=2)
    names = [m for d in plan.fused for m in d.members] + list(plan.singles)
    assert "norm" in names and "mm" in names
    assert not any(CHAIN_SEP in n for n in names)


def test_chain_renders_in_plan_summary():
    plan = planner.plan(_epilogue_graph(), max_ways=2)
    assert any(CHAIN_SEP in r["members"] for r in plan.summary())


# ---------------------------------------------------------------------------
# ScheduleCache regression: chain structure is part of the identity
# ---------------------------------------------------------------------------
def test_bundle_signature_distinguishes_chain_structure():
    norm = rmsnorm_op(R=32, d=128, dtype=jnp.float32, bm=16)
    mm = matmul_1d_op(M=32, K=128, N=128, dtype=jnp.float32, bm=16)
    chain = stitch(norm, mm, "x")
    # same name/operands/flops/bytes, chain markers stripped — the v2 bug
    # this guards against: a stitched bundle resolving an unstitched entry
    impostor = dataclasses.replace(chain, chain=(), extra_vmem_bytes=0)
    sig = bundle_signature([chain], vmem_budget=1 << 20)
    assert sig != bundle_signature([impostor], vmem_budget=1 << 20)
    # extra VMEM residency alone changes the tuning problem too
    fatter = dataclasses.replace(chain,
                                 extra_vmem_bytes=chain.extra_vmem_bytes * 2)
    assert sig != bundle_signature([fatter], vmem_budget=1 << 20)


def test_cache_version_bump_discards_v2_entries(tmp_path):
    path = tmp_path / "sched.json"
    import json
    path.write_text(json.dumps({
        "version": 2,
        "entries": {"deadbeef": {"members": ["a"], "ratios": [1],
                                 "variant": 0, "vmem_cap": None,
                                 "predicted_s": 1.0, "measured_s": None,
                                 "delta_pct": None, "mode": "costmodel"}},
        "meta": {"deadbeef": {"last_used": 1, "uses": 1}}, "clock": 1}))
    cache = ScheduleCache(path)
    assert len(cache) == 0, "pre-chain schedule survived the version bump"
