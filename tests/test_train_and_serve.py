"""End-to-end training (loss decreases, grad-accum equivalence, hfused-Adam
path parity) and the serving engine (greedy output matches step-by-step
decode oracle)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import lm
from repro.serve.engine import Request, ServeEngine
from repro.train import optimizer as opt_mod
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import TrainConfig, make_train_step


def _cfg():
    return dataclasses.replace(get_config("granite-3-2b").reduced(),
                               dtype="float32")


def test_loss_decreases_over_training(rng):
    cfg = _cfg()
    params = lm.init(cfg, rng)
    opt = opt_mod.init(params)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=3e-3, warmup_steps=2,
                                             total_steps=30),
                       remat=False)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4))
    losses = []
    for step in range(25):
        batch = jax.tree.map(jnp.asarray, data.batch_at(step % 4))
        params, opt, metrics = step_fn(params, opt, batch, jnp.asarray(step))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_grad_accum_matches_full_batch(rng):
    cfg = _cfg()
    params = lm.init(cfg, rng)
    opt = opt_mod.init(params)
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=8))
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))
    f1 = make_train_step(cfg, TrainConfig(remat=False, grad_accum=1))
    f4 = make_train_step(cfg, TrainConfig(remat=False, grad_accum=4))
    p1, _, m1 = f1(params, opt, batch, jnp.asarray(0))
    p4, _, m4 = f4(params, opt, batch, jnp.asarray(0))
    # losses are means over the same tokens; grads averaged — params close
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_hfused_adam_training_parity(rng):
    """Optimizer with hfused Pallas kernel (interpret) == jnp path."""
    from repro.kernels import ops as kops
    cfg = _cfg()
    params = lm.init(cfg, rng)
    grads = jax.tree.map(lambda p: p * 0.01 + 0.001, params)
    opt = opt_mod.init(params)
    ocfg = AdamWConfig()
    p_ref, s_ref = opt_mod.update(ocfg, grads, opt, params)

    kops.force("interpret")
    try:
        cnt = opt.count + 1
        bc1 = 1 - ocfg.b1 ** cnt.astype(jnp.float32)
        bc2 = 1 - ocfg.b2 ** cnt.astype(jnp.float32)
        lr = opt_mod.schedule(ocfg, cnt)
        p_fused, m_fused, v_fused = kops.hfused_adamw(
            params, grads, opt.m, opt.v, lr=lr, b1=ocfg.b1, b2=ocfg.b2,
            eps=ocfg.eps, wd=ocfg.weight_decay, bc1=bc1, bc2=bc2)
    finally:
        kops.force(None)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_fused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_checkpoint_restart_training(tmp_path, rng):
    """Train 6 steps; crash; resume from ckpt at 4; final params equal an
    uninterrupted run (deterministic data + optimizer)."""
    from repro.train import checkpoint as ckpt
    cfg = _cfg()
    tcfg = TrainConfig(remat=False,
                       optimizer=AdamWConfig(lr=1e-3, warmup_steps=1,
                                             total_steps=10))
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=4))

    def run(start, params, opt, stop):
        for s in range(start, stop):
            batch = jax.tree.map(jnp.asarray, data.batch_at(s))
            params, opt, _ = step_fn(params, opt, batch, jnp.asarray(s))
        return params, opt

    p0 = lm.init(cfg, rng)
    o0 = opt_mod.init(p0)
    p_full, _ = run(0, p0, o0, 6)

    p_a, o_a = run(0, p0, o0, 4)
    ckpt.save(tmp_path, 4, {"params": p_a, "m": o_a.m, "v": o_a.v})
    step, tree, _ = ckpt.restore_latest(tmp_path,
                                        {"params": p_a, "m": o_a.m, "v": o_a.v})
    o_b = opt_mod.OptState(m=tree["m"], v=tree["v"],
                           count=jnp.asarray(step, jnp.int32))
    p_resumed, _ = run(4, tree["params"], o_b, 6)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_serve_engine_matches_manual_decode(rng):
    cfg = _cfg()
    params = lm.init(cfg, rng)
    engine = ServeEngine(cfg, params, batch=2, max_len=32)
    prompts = [np.arange(1, 9, dtype=np.int32),
               np.arange(3, 11, dtype=np.int32)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    engine.run(reqs)

    # oracle: greedy decode via lm directly
    toks = jnp.stack([jnp.asarray(p) for p in prompts])
    cache, logits = lm.prefill(cfg, params, {"tokens": toks}, max_len=32)
    want = [[], []]
    cur = jnp.argmax(logits, -1)
    for i in range(2):
        want[i].append(int(cur[i]))
    for _ in range(3):
        logits, cache = lm.decode_step(cfg, params, cache, cur)
        cur = jnp.argmax(logits, -1)
        for i in range(2):
            want[i].append(int(cur[i]))
    assert [r.out_tokens for r in reqs] == want


def test_compression_roundtrip_error_feedback():
    from repro.distributed.compression import compress_roundtrip
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    resid = jnp.zeros_like(g)
    acc_true = jnp.zeros_like(g)
    acc_hat = jnp.zeros_like(g)
    for _ in range(50):
        g_hat, resid = compress_roundtrip(g, resid)
        acc_true += g
        acc_hat += g_hat
    # error feedback keeps the long-run average unbiased
    rel = float(jnp.linalg.norm(acc_hat - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 1e-3
