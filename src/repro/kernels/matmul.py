"""Tiled matmul Pallas kernel (MXU-aligned BlockSpecs, fp32 VMEM accumulator).

Two forms:
  * ``matmul``      — 3-D grid (m, n, k) with K-streaming and a VMEM
                      accumulator; the standalone high-performance form.
  * ``matmul_1d_op``— fusible OpSpec (1-D grid over M row-blocks, weights
                      resident): the compute-bound partner the horizontal-
                      fusion planner pairs with memory-bound ops (decode
                      attention, optimizer updates, norms).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.op_spec import OpSpec, Operand


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(x: jax.Array, w: jax.Array, *, bm: int = 512, bn: int = 512,
           bk: int = 512, interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N), tiled (bm, bn, bk)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    try:
        from jax.experimental.pallas import tpu as pltpu
        scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    except Exception:
        scratch = [pl.MemorySpace.ANY((bm, bn), jnp.float32)]  # pragma: no cover
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(M // bm, N // bn, nk),
        in_specs=[pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
                  pl.BlockSpec((bk, bn), lambda m, n, k: (k, n))],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, w)


def matmul_1d_op(M: int, K: int, N: int, dtype=jnp.bfloat16,
                 bm: int = 256) -> OpSpec:
    """Fusible form: grid over M row-blocks; (K, N) weight resident in VMEM."""
    assert M % bm == 0

    def body(step, x_ref, w_ref, o_ref):
        o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                             preferred_element_type=jnp.float32
                             ).astype(o_ref.dtype)

    itemsize = jnp.dtype(dtype).itemsize
    return OpSpec(
        name=f"matmul_{M}x{K}x{N}", grid=M // bm, body=body,
        inputs=(Operand((M, K), dtype, (bm, K), lambda s: (s, 0)),
                Operand((K, N), dtype, (K, N), lambda s: (0, 0))),
        outputs=(Operand((M, N), dtype, (bm, N), lambda s: (s, 0)),),
        flops=2.0 * M * K * N,
        hbm_bytes=(M * K + K * N + M * N) * itemsize,
        tag="framework:matmul",
        in_names=("x", "w"), out_names=("out",))
