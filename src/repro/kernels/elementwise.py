"""Row-streamed elementwise Pallas kernels — the epilogue consumers.

These are the tiny memory-bound ops a matmul's output classically flows
into (activation, residual add).  Standalone they are pure HBM round-trips;
their whole point is to be *stitched* onto their producer via
``core/stitch.py`` so the intermediate never leaves registers.  Block
layout mirrors ``matmul_1d_op``'s output ((bm, F) row blocks, map
s -> (s, 0)) so ``can_stitch``'s identical-block case applies directly.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.op_spec import OpSpec, Operand


def activation_op(R: int, F_in: int, F_out: int, fn: Callable,
                  dtype=jnp.bfloat16, bm: int = 256,
                  name: str | None = None) -> OpSpec:
    """out = fn(h) row-wise; h: (R, F_in) -> out: (R, F_out).

    ``fn`` maps a (bm, F_in) block to (bm, F_out) — gated activations
    (silu/gelu-and-multiply) halve F, plain ones keep it.  It must be
    shape-polymorphic in the row dim so the block-shrink variants stay
    valid.
    """
    bm = min(bm, R)
    assert R % bm == 0

    def body(step, h_ref, o_ref):
        o_ref[...] = fn(h_ref[...]).astype(o_ref.dtype)

    itemsize = jnp.dtype(dtype).itemsize
    return OpSpec(
        name=name or f"act_{R}x{F_in}", grid=R // bm, body=body,
        inputs=(Operand((R, F_in), dtype, (bm, F_in), lambda s: (s, 0)),),
        outputs=(Operand((R, F_out), dtype, (bm, F_out), lambda s: (s, 0)),),
        flops=8.0 * R * F_in,
        hbm_bytes=float(R * (F_in + F_out)) * itemsize,
        tag="framework:activation",
        in_names=("h",), out_names=("out",))


def silu_gate(h: jax.Array) -> jax.Array:
    """SwiGLU epilogue: h = [a | b] (gated halves) -> silu(a) * b."""
    f = h.shape[-1] // 2
    a, b = h[..., :f], h[..., f:]
    af = a.astype(jnp.float32)
    return (af * jax.nn.sigmoid(af)) * b.astype(jnp.float32)


def gelu_gate(h: jax.Array) -> jax.Array:
    f = h.shape[-1] // 2
    a, b = h[..., :f], h[..., f:]
    return jax.nn.gelu(a.astype(jnp.float32)) * b.astype(jnp.float32)


def gelu_plain(h: jax.Array) -> jax.Array:
    return jax.nn.gelu(h.astype(jnp.float32))


def relu2(h: jax.Array) -> jax.Array:
    return jnp.square(jax.nn.relu(h.astype(jnp.float32)))


def residual_add_op(R: int, F: int, dtype=jnp.bfloat16, bm: int = 256,
                    name: str | None = None) -> OpSpec:
    """out = h + res row-wise — the matmul→residual-add epilogue."""
    bm = min(bm, R)
    assert R % bm == 0
    blk = lambda s: (s, 0)

    def body(step, h_ref, r_ref, o_ref):
        o_ref[...] = (h_ref[...].astype(jnp.float32)
                      + r_ref[...].astype(jnp.float32)).astype(o_ref.dtype)

    itemsize = jnp.dtype(dtype).itemsize
    return OpSpec(
        name=name or f"resadd_{R}x{F}", grid=R // bm, body=body,
        inputs=(Operand((R, F), dtype, (bm, F), blk),
                Operand((R, F), dtype, (bm, F), blk)),
        outputs=(Operand((R, F), dtype, (bm, F), blk),),
        flops=1.0 * R * F,
        hbm_bytes=3.0 * R * F * itemsize,
        tag="framework:residual_add",
        in_names=("h", "res"), out_names=("out",))
