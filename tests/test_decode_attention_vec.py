"""Property test for the vectorized (per-slot) dynamic-length decode
attention: for random per-slot lengths (B,), the masked fused kernel equals
a per-row reference computed at each slot's OWN length — the operand
contract the continuous-batching engine binds ``pos + 1`` to."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (see "
                           "requirements.txt); a deterministic per-slot "
                           "length case lives in test_kernels_framework.py")
from hypothesis import given, settings, strategies as st

from repro.core import hfuse
from repro.kernels.decode_attention import decode_attention_op

S, H, Hkv, D, CK = 64, 4, 2, 8, 32


def _ref_row(q_b, k_b, v_b, L):
    """Full-softmax decode attention for ONE slot at ITS length L."""
    rep = H // Hkv
    qg = q_b.reshape(Hkv, rep, D)
    s = np.einsum("hrd,khd->hrk", qg, k_b[:L]) / math.sqrt(D)
    s = s - s.max(-1, keepdims=True)
    w = np.exp(s)
    w = w / w.sum(-1, keepdims=True)
    return np.einsum("hrk,khd->hrd", w, v_b[:L]).reshape(H, D)


@settings(deadline=None, max_examples=12)
@given(lens=st.lists(st.integers(1, S), min_size=1, max_size=4),
       seed=st.integers(0, 2 ** 16))
def test_vectorized_lengths_match_per_row_reference(lens, seed):
    B = len(lens)
    op = decode_attention_op(B=B, S=S, H=H, Hkv=Hkv, D=D, dtype=jnp.float32,
                             ck=CK, dynamic_length=True)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    lens_arr = jnp.asarray(np.asarray(lens, np.int32).reshape(B, 1))
    o, _m, _l = hfuse.run_single(op, interpret=True)(lens_arr, q, k, v)
    qn, kn, vn = (np.asarray(a) for a in (q, k, v))
    want = np.stack([_ref_row(qn[b], kn[b], vn[b], lens[b])
                     for b in range(B)])
    np.testing.assert_allclose(np.asarray(o), want, atol=3e-5, rtol=1e-4)
