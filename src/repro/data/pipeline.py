"""Deterministic, sharded, restartable token pipeline.

  * deterministic — batch(step, shard) is a pure function of (seed, step,
                    shard): any host can recompute any batch; restart at
                    step k reproduces exactly the stream a continuous run
                    would have seen (checkpointable by step index alone).
  * sharded       — each data-parallel host materializes only its slice.
  * skip-ahead    — straggler mitigation: a host that fell behind jumps the
                    cursor (sacrifices examples, preserves alignment).
  * file-backed   — optional memmap token file; synthetic Zipf tokens
                    otherwise (self-contained benchmarks).
  * prefetch      — background thread keeps `depth` batches ready.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    token_file: Optional[str] = None       # memmap int32 tokens
    num_codebooks: int = 0                 # audio: (B, K, S) batches
    num_image_tokens: int = 0              # vlm: also emit pixel embeds
    d_model: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        self.step = 0
        self._tokens = None
        if cfg.token_file:
            self._tokens = np.memmap(cfg.token_file, dtype=np.int32, mode="r")

    # ------------------------------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "shard": self.shard}

    def restore(self, state: dict):
        self.step = int(state["step"])

    def skip_ahead(self, n: int = 1):
        """Straggler mitigation: drop n steps of this shard's data."""
        self.step += n

    # ------------------------------------------------------------------
    def _synthetic(self, step: int) -> np.ndarray:
        cfg = self.cfg
        K = max(1, cfg.num_codebooks)
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[step, self.shard, 0, 0]))
        # Zipf-ish marginal over the vocab (realistic softmax pressure)
        z = rng.zipf(1.3, size=(self.local_batch, K, cfg.seq_len + 1))
        return (z % cfg.vocab_size).astype(np.int32)

    def _from_file(self, step: int) -> np.ndarray:
        cfg = self.cfg
        K = max(1, cfg.num_codebooks)
        need = self.local_batch * K * (cfg.seq_len + 1)
        start = ((step * self.num_shards + self.shard) * need) % \
            max(1, len(self._tokens) - need)
        chunk = np.asarray(self._tokens[start:start + need])
        return chunk.reshape(self.local_batch, K, cfg.seq_len + 1) \
            % self.cfg.vocab_size

    def batch_at(self, step: int) -> dict:
        toks = (self._from_file(step) if self._tokens is not None
                else self._synthetic(step))
        cfg = self.cfg
        if cfg.num_codebooks:
            batch = {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}
        else:
            batch = {"tokens": toks[:, 0, :-1], "labels": toks[:, 0, 1:]}
        if cfg.num_image_tokens:
            rng = np.random.Generator(np.random.Philox(
                key=cfg.seed + 1, counter=[step, self.shard, 0, 0]))
            batch["pixel_embeds"] = rng.standard_normal(
                (self.local_batch, cfg.num_image_tokens, cfg.d_model),
                dtype=np.float32)
            # image positions don't contribute to the LM loss
            batch["labels"][:, : cfg.num_image_tokens] = -1
        return batch

    def __next__(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def __iter__(self) -> Iterator[dict]:
        return self


class Prefetcher:
    """Background-thread prefetch queue over any batch iterator."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self.it = it
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            for b in self.it:
                if self._stop.is_set():
                    return
                self.q.put(b)
        finally:
            self.q.put(None)

    def __next__(self):
        b = self.q.get()
        if b is None:
            raise StopIteration
        return b

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
