"""Shared benchmark plumbing.

Methodology note (CPU container, TPU v5e target): "time" columns are derived
from the three-term roofline cost model over the *exact* FLOP/byte counts of
each kernel (the same model the autotuner uses, validated against compiled-
HLO counts in the dry-run); wall-clock on this host would measure the Python
interpreter, not the TPU.  Functional equivalence of every fused kernel is
asserted in interpret mode before its row is reported — a row in these
tables is a kernel that RUNS and matches its oracle.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np


def check_pair_numerics(opA, mkA, refA, opB, mkB, refB, sched) -> float:
    """Build the fused kernel, run in interpret mode, return max |err|."""
    from repro.core import hfuse
    xa = mkA(jax.random.PRNGKey(0))
    xb = mkB(jax.random.PRNGKey(1))
    fused = hfuse.generate(opA, opB, sched, interpret=True)
    outs = fused(*xa, *xb)
    wa, wb = refA(*xa), refB(*xb)
    wa = wa if isinstance(wa, tuple) else (wa,)
    wb = wb if isinstance(wb, tuple) else (wb,)
    err = 0.0
    for got, want in zip(outs, (*wa, *wb)):
        err = max(err, float(np.max(np.abs(
            np.asarray(got, np.float32) - np.asarray(want, np.float32)))))
    return err


def check_bundle_numerics(ops, mks, refs, sched) -> float:
    """Build the N-way fused bundle, run in interpret mode, return max |err|."""
    from repro.core import hfuse
    xs = [mk(jax.random.PRNGKey(i)) for i, mk in enumerate(mks)]
    fused = hfuse.generate(ops, sched, interpret=True)
    outs = fused(*[a for x in xs for a in x])
    err, off = 0.0, 0
    for x, ref in zip(xs, refs):
        want = ref(*x)
        want = want if isinstance(want, tuple) else (want,)
        for w in want:
            err = max(err, float(np.max(np.abs(
                np.asarray(outs[off], np.float32) - np.asarray(w, np.float32)))))
            off += 1
    return err


def csv_row(*cols):
    print(",".join(str(c) for c in cols), flush=True)
