"""Benchmark driver — one section per paper table/figure + the framework
integration table + the roofline summary.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Time columns are cost-model derived over exact FLOP/byte counts (TPU v5e
targets; this host is CPU-only — see benchmarks/common.py §Methodology);
every HFuse row's kernel is numerics-verified in interpret mode.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip interpret-mode numerics verification")
    args = ap.parse_args()

    from benchmarks import fig7_pairs, fig8_kernels, fig9_fused, fig_framework
    from benchmarks import roofline

    print("# === fig8: individual kernel metrics (paper Fig. 8) ===")
    t0 = time.time()
    fig8_kernels.run()
    print(f"# fig8 done in {time.time() - t0:.1f}s\n")

    print("# === fig7: 16 pairs x workload ratios (paper Fig. 7) ===")
    t0 = time.time()
    fig7_pairs.run(check_numerics=not args.fast)
    print(f"# fig7 done in {time.time() - t0:.1f}s\n")

    print("# === fig9: fused metrics ±VMEM cap (paper Fig. 9, RegCap) ===")
    t0 = time.time()
    fig9_fused.run()
    print(f"# fig9 done in {time.time() - t0:.1f}s\n")

    print("# === framework integration (beyond-paper; DESIGN.md §4) ===")
    t0 = time.time()
    fig_framework.run()
    print(f"# framework done in {time.time() - t0:.1f}s\n")

    print("# === roofline summary (from dry-run artifacts; §Roofline) ===")
    t0 = time.time()
    roofline.run()
    print(f"# roofline done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
