"""xLSTM-1.3B — sLSTM + mLSTM residual blocks [arXiv:2405.04517; unverified]

48 layers, d_model 2048, 4 heads (kv=4), d_ff=0 (blocks carry their own
up/down projections), vocab 50304.  Block ratio mLSTM:sLSTM = 7:1
(the paper's xLSTM[7:1] notation), i.e. every 8th block is sLSTM.
"""
from repro.configs.base import ModelConfig, MLSTM, SLSTM, register


@register("xlstm-1.3b")
def config() -> ModelConfig:
    pattern = tuple(([MLSTM] * 7 + [SLSTM]) * 6)   # 48 layers
    return ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        num_layers=48,
        block_pattern=pattern,
        d_model=2048,
        num_heads=4,
        num_kv_heads=4,
        head_dim=512,               # d_model / heads for the mLSTM memory
        d_ff=0,                     # no separate FFN block
        vocab_size=50_304,
        activation="gelu_mlp",
        norm="layernorm",
        mlstm_proj_factor=2.0,
        slstm_proj_factor=4.0 / 3.0,
        source="[arXiv:2405.04517; unverified] xLSTM[7:1]",
    )
