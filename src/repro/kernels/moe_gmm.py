"""Grouped expert matmul (MoE FFN) Pallas kernel.

THE clearest framework instance of the paper's idea: E independent expert
FFNs — each a small matmul that would underutilize the MXU and pay E kernel
launches — horizontally fused into one kernel whose grid covers
(expert, token-block) tiles.  DeepSeek-V2: 160-way fusion; Phi-3.5: 16-way.

Gate/up are one fused (d, 2f) weight (the shared-input fusion case).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.op_spec import OpSpec, Operand


def _gmm_kernel(x_ref, win_ref, wout_ref, o_ref, *, act: str, gated: bool):
    x = x_ref[0]                                         # (bc, d)
    h = jnp.dot(x, win_ref[0], preferred_element_type=jnp.float32)
    if gated:
        g, u = jnp.split(h, 2, axis=-1)
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        h = g * u
    else:
        h = jax.nn.gelu(h)
    o_ref[0] = jnp.dot(h.astype(x.dtype), wout_ref[0],
                       preferred_element_type=jnp.float32).astype(o_ref.dtype)


def moe_gmm(xe, w_in, w_out, *, act: str = "silu", bc: int = 128,
            interpret: bool = False):
    """xe: (E, C, d); w_in: (E, d, 2f|f); w_out: (E, f, d) -> (E, C, d)."""
    E, C, d = xe.shape
    f = w_out.shape[1]
    gated = w_in.shape[-1] == 2 * f
    bc = min(bc, C)
    assert C % bc == 0
    return pl.pallas_call(
        functools.partial(_gmm_kernel, act=act, gated=gated),
        grid=(E, C // bc),
        in_specs=[pl.BlockSpec((1, bc, d), lambda e, c: (e, c, 0)),
                  pl.BlockSpec((1, d, w_in.shape[-1]), lambda e, c: (e, 0, 0)),
                  pl.BlockSpec((1, f, d), lambda e, c: (e, 0, 0))],
        out_specs=pl.BlockSpec((1, bc, d), lambda e, c: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), xe.dtype),
        interpret=interpret,
    )(xe, w_in, w_out)


def moe_gmm_op(E: int, C: int, d: int, f: int, dtype=jnp.bfloat16,
               bc: int = 128, act: str = "silu", gated: bool = True) -> OpSpec:
    """Fusible 1-D form: grid over (expert, token-block) linearized.

    ``bc`` is clamped like ``moe_gmm`` does (min(bc, C)), then rounded
    down to a divisor of C — a serving-scale capacity of 8 against the
    default bc=128 builds a (1, 8, d) block instead of failing the
    divisibility assert."""
    bc = min(bc, C)
    while C % bc:
        bc -= 1
    nc = C // bc
    fin = 2 * f if gated else f

    def body(step, x_ref, win_ref, wout_ref, o_ref):
        _gmm_kernel(x_ref, win_ref, wout_ref, o_ref, act=act, gated=gated)

    itemsize = jnp.dtype(dtype).itemsize
    return OpSpec(
        name=f"moe_gmm_E{E}_C{C}", grid=E * nc, body=body,
        inputs=(Operand((E, C, d), dtype, (1, bc, d),
                        lambda s: (s // nc, s % nc, 0)),
                Operand((E, d, fin), dtype, (1, d, fin),
                        lambda s: (s // nc, 0, 0)),
                Operand((E, f, d), dtype, (1, f, d),
                        lambda s: (s // nc, 0, 0))),
        outputs=(Operand((E, C, d), dtype, (1, bc, d),
                         lambda s: (s // nc, s % nc, 0)),),
        flops=2.0 * E * C * d * (fin + f),
        hbm_bytes=(2 * E * C * d + E * d * fin + E * f * d) * itemsize,
        tag="framework:moe_gmm",
        in_names=("xe", "w_in", "w_out"), out_names=("ye",))
