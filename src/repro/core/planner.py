"""Graph-level fusion planner — decides WHICH independent ops to fuse.

The paper fuses kernels that happen to be co-resident (different CUDA
streams, e.g. Batchnorm during training + Hist from a monitoring pass).  In
a framework we know the whole op graph, so the planner:

  1. classifies every op by roofline bound (compute vs memory),
  2. builds the dependency closure (never fuse ops on a dependent path),
  3. greedily pairs memory-bound with compute-bound ops whose native times
     are closest (the paper's Fig. 7: gains peak at execution-time ratio ~1),
  4. runs the autotuner on each pair and keeps pairs with predicted gain
     above a threshold — the paper's negative results (Blake256+SHA256
     loses) become planner rejections.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core import autotuner
from repro.core.cost_model import fusion_profitable
from repro.core.op_spec import OpSpec


@dataclass
class GraphOp:
    op: OpSpec
    deps: frozenset[str] = frozenset()       # names of ops this one reads from


@dataclass
class FusionDecision:
    a: str
    b: str
    result: autotuner.SearchResult
    predicted_speedup_pct: float


@dataclass
class FusionPlan:
    fused: list[FusionDecision]
    singles: list[str]
    rejected: list[tuple[str, str, str]]     # (a, b, reason)

    def summary(self) -> list[dict]:
        rows = [{
            "pair": f"{d.a}+{d.b}",
            "schedule": f"{d.result.best.sched.ra}:{d.result.best.sched.rb}",
            "vmem_cap": d.result.best.vmem_cap,
            "predicted_speedup_pct": round(d.predicted_speedup_pct, 1),
        } for d in self.fused]
        rows += [{"pair": s, "schedule": "-", "predicted_speedup_pct": 0.0}
                 for s in self.singles]
        return rows


def _reachable(ops: dict[str, GraphOp]) -> dict[str, frozenset]:
    """Transitive dependency closure."""
    memo: dict[str, frozenset] = {}

    def visit(n: str) -> frozenset:
        if n in memo:
            return memo[n]
        acc = set(ops[n].deps)
        for d in ops[n].deps:
            if d in ops:
                acc |= visit(d)
        memo[n] = frozenset(acc)
        return memo[n]

    for n in ops:
        visit(n)
    return memo


def independent(ops: dict[str, GraphOp], a: str, b: str) -> bool:
    clo = _reachable(ops)
    return b not in clo[a] and a not in clo[b]


def plan(graph: Sequence[GraphOp], *, min_gain_pct: float = 2.0,
         allow_same_bound: bool = False) -> FusionPlan:
    ops = {g.op.name: g for g in graph}
    mem = sorted((g.op for g in graph if g.op.bound == "memory"),
                 key=lambda o: -o.t_native)
    comp = sorted((g.op for g in graph if g.op.bound == "compute"),
                  key=lambda o: -o.t_native)

    used: set[str] = set()
    fused: list[FusionDecision] = []
    rejected: list[tuple[str, str, str]] = []

    for m in mem:
        if m.name in used:
            continue
        # closest-native-time compute partner (paper: ratio ~1 is best)
        partners = [c for c in comp if c.name not in used
                    and independent(ops, m.name, c.name)]
        if not partners and allow_same_bound:
            partners = [c.op for c in graph
                        if c.op.name not in used and c.op.name != m.name
                        and independent(ops, m.name, c.op.name)]
        if not partners:
            continue
        c = min(partners, key=lambda o: abs(o.t_native - m.t_native))
        res = autotuner.search((m, c))
        gain = res.best.est.speedup_pct()
        if gain >= min_gain_pct:
            fused.append(FusionDecision(m.name, c.name, res, gain))
            used |= {m.name, c.name}
        else:
            rejected.append((m.name, c.name,
                             f"predicted gain {gain:.1f}% < {min_gain_pct}%"))

    singles = [g.op.name for g in graph if g.op.name not in used]
    return FusionPlan(fused=fused, singles=singles, rejected=rejected)
