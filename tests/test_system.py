"""End-to-end behaviour of the public API surface (the paper's system):
OpSpec -> planner -> autotuner -> generated fused kernel -> numerics,
plus the CLI entry points."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return env


def test_whole_pipeline_plan_build_run(rng):
    """The README quickstart, as a test: describe two kernels, let the
    planner decide, build the fused kernel, check numerics + prediction."""
    from repro.core import planner
    from repro.kernels import paper_suite as ps

    eth, mk_e, ref_e = ps.make_ethash_like(R_dag=2048, bm=256)
    bl, mk_b, ref_b = ps.make_blake_like(R=1024, bm=256)
    plan = planner.plan([planner.GraphOp(eth), planner.GraphOp(bl)])
    assert len(plan.fused) == 1
    decision = plan.fused[0]
    assert decision.predicted_speedup_pct > 10.0   # paper: +15.9..65.8%

    fused = decision.result.build(interpret=True)
    xa, xb = mk_e(rng), mk_b(jax.random.PRNGKey(1))
    outs = fused(*xa, *xb)
    np.testing.assert_allclose(np.asarray(outs[0]),
                               np.asarray(ref_e(*xa)), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(outs[1], np.float32),
                               np.asarray(ref_b(*xb), np.float32),
                               rtol=1e-4, atol=1e-4)


def test_train_cli_smoke(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "granite-3-2b",
         "--scale", "smoke", "--steps", "6", "--batch", "2", "--seq", "32",
         "--ckpt-dir", str(tmp_path), "--ckpt-every", "3", "--log-every", "2"],
        capture_output=True, text=True, timeout=600, env=_env())
    assert out.returncode == 0, out.stderr[-2000:]
    assert "final loss" in out.stdout
    assert list(tmp_path.glob("step_*"))


def test_serve_cli_smoke():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "recurrentgemma-2b", "--requests", "3", "--prompt-len", "8",
         "--max-new", "4", "--batch", "2"],
        capture_output=True, text=True, timeout=600, env=_env())
    assert out.returncode == 0, out.stderr[-2000:]
    assert "served 3 requests" in out.stdout
