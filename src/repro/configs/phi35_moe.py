"""Phi-3.5-MoE (42B total / 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct]

32 layers, d_model 4096, 32 heads (GQA kv=8), 16 experts top-2 with
d_ff 6400 each (SwiGLU), vocab 32064, LayerNorm.
"""
from repro.configs.base import MoEConfig, ModelConfig, register


@register("phi3.5-moe-42b-a6.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32_064,
        activation="silu",
        norm="layernorm",
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400),
        source="[hf:microsoft/Phi-3.5-MoE-instruct; hf] 16 experts top-2",
    )


@register("phi3.5-moe-rms")
def config_rms() -> ModelConfig:
    """Phi-3.5-MoE shape with RMSNorm — the MoE config the executed serve
    path targets (the executor's norm kernel is rmsnorm-only, so the
    faithful LayerNorm variant above still serves on the fallback).
    ``reduced()`` of this config is the MoE serve smoke/CI model."""
    import dataclasses
    return dataclasses.replace(
        config(), name="phi3.5-moe-rms", norm="rmsnorm",
        source="phi3.5-moe-42b-a6.6b with rmsnorm (executed-serve variant)")
