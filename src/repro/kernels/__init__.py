from repro.kernels import (adam, decode_attention, flash_attention, matmul,
                           moe_gmm, ops, paper_suite, ref, rmsnorm)  # noqa: F401
