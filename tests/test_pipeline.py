"""Pipeline parallelism over the pod axis: GPipe schedule == sequential
application, verified numerically on 8 fake devices (2 pods x 2 data x 2
model) in a subprocess."""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed.pipeline import pipeline_over_pods

    mesh = make_debug_mesh((2, 2, 2), ("pod", "data", "model"))
    key = jax.random.PRNGKey(0)
    d = 16
    # two homogeneous stages, each a 2-layer MLP
    W = jax.random.normal(key, (2, 2, d, d), jnp.float32) * 0.3   # (stage,layer,d,d)

    def stage_fn(params, x):
        for i in range(2):
            x = jnp.tanh(x @ params[i])
        return x

    M, B = 4, 8
    xs = jax.random.normal(jax.random.PRNGKey(1), (M, B, d), jnp.float32)

    run = pipeline_over_pods(stage_fn, mesh, n_stages=2)
    W_sh = jax.device_put(W, NamedSharding(mesh, P("pod")))
    ys = jax.jit(run)(W_sh, xs)

    # oracle: sequential stages
    want = xs
    for s in range(2):
        want = jax.vmap(lambda x: stage_fn(W[s], x))(want)
    err = float(jnp.max(jnp.abs(ys - want)))
    assert err < 1e-5, err
    # collective-permute present in the compiled module
    txt = jax.jit(run).lower(W_sh, xs).compile().as_text()
    assert "collective-permute" in txt
    print("PIPELINE OK", err)
""")


def test_gpipe_matches_sequential():
    out = subprocess.run([sys.executable, "-c", CODE.format(src=SRC)],
                         capture_output=True, text=True, timeout=600)
    assert "PIPELINE OK" in out.stdout, out.stderr[-3000:]
