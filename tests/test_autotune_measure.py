"""Measurement-driven autotuning: the two-stage (top-K + coordinate-descent)
search, the timing harness's interpret proxy (the CI stand-in for the
paper's profiler), automatic block-shrink variants, the persistent schedule
cache, and the planner's zero-re-search path.  Hypothesis-free by design —
this coverage must run everywhere CI does."""
import itertools

import jax
import numpy as np
import pytest

from repro.core import autotuner, hfuse, op_spec, planner, timing
from repro.core.cost_model import (VMEM_BUDGET, Schedule, hfused_cost,
                                   ratio_candidates)
from repro.core.schedule_cache import (ScheduleCache, bundle_signature,
                                       default_cache)
from repro.kernels import paper_suite as ps


def _bundle(names):
    return ps.make_bundle(names, small=True)


def _counting(measure):
    calls = []

    def counted(fused, *ops):
        calls.append(fused)
        return measure(fused, *ops)
    counted.backend = getattr(measure, "backend", "interpret")
    return counted, calls


def _lattice_best(ops, vmem_budget=VMEM_BUDGET):
    """The exhaustive stage-1 lattice, recomputed independently."""
    best, size = None, 0
    caps = [None]
    if 2 * sum(op.vmem_bytes for op in ops) > vmem_budget:
        caps.append(vmem_budget)
    for sched in ratio_candidates(ops):
        for cap in caps:
            t = hfused_cost(ops, sched, vmem_budget=cap or vmem_budget).t_hfused
            best = t if best is None else min(best, t)
            size += 1
    return best, size


# ---------------------------------------------------------------------------
# measured-mode search semantics
# ---------------------------------------------------------------------------
def test_stub_measure_inverting_cost_ranking_flips_best():
    """A measure that deliberately inverts the cost model's ranking must
    flip SearchResult.best — measurement outranks the model, always."""
    ops, _, _ = _bundle(("ethash_like", "hist", "blake_like"))

    def inverted(fused, *bundle_ops):
        est = hfused_cost(bundle_ops, fused.schedule)
        return 1.0 / max(est.t_hfused, 1e-30)       # model's best -> worst

    res_cm = autotuner.search(tuple(ops))
    res_m = autotuner.search(tuple(ops), measure=inverted)
    assert res_m.best.measured_s is not None
    measured = [c for c in res_m.log if c.measured_s is not None]
    assert res_m.best.measured_s == min(c.measured_s for c in measured)
    # the model's favourite scores worst under the inverted measure, so the
    # measured winner must be a different schedule
    assert res_m.best.sched != res_cm.best.sched


def test_interpret_harness_runs_measured_path_in_ci():
    """make_measure('interpret') drives the identical top-K + coordinate-
    descent path, deterministically, with delta columns in the table."""
    ops, _, _ = _bundle(("maxpool", "upsample", "sha_like"))
    measure = timing.make_measure("interpret")
    res1 = autotuner.search(tuple(ops), measure=measure)
    res2 = autotuner.search(tuple(ops), measure=measure)
    assert res1.n_measured > 0
    assert res1.best.sched == res2.best.sched           # deterministic proxy
    assert res1.best.measured_s == res2.best.measured_s
    deltas = [r["cm_vs_measured_delta_pct"] for r in res1.table()
              if r["measured_s"] is not None]
    assert len(deltas) == res1.n_measured
    assert all(d is not None for d in deltas)


@pytest.mark.parametrize("names", ps.paper_triples())
def test_measured_evals_bounded_below_lattice(names):
    """Acceptance: measure() runs on at most top_k + cd_budget candidates —
    strictly fewer than the exhaustive lattice for every registered
    3-way paper_suite bundle."""
    ops, _, _ = _bundle(names)
    counted, calls = _counting(timing.make_measure("interpret"))
    res = autotuner.search(tuple(ops), measure=counted, top_k=3, cd_budget=4)
    _, lattice = _lattice_best(tuple(ops))
    assert res.lattice_size == lattice
    assert len(calls) == res.n_measured <= 3 + 4
    assert res.n_measured < lattice


@pytest.mark.parametrize("names", ps.paper_triples()
                         + [("ethash_like", "blake_like"),
                            ("maxpool", "sha_like")])
def test_coordinate_descent_never_worse_than_lattice(names):
    """Property: the refined schedule is never worse (cost model) than the
    best exhaustive-lattice candidate, for every registered bundle."""
    ops, _, _ = _bundle(names)
    res = autotuner.search(tuple(ops))
    lattice_best, _ = _lattice_best(tuple(ops))
    assert res.best.est.t_hfused <= lattice_best * (1 + 1e-12)
    # CD never duplicates a lattice evaluation (known-candidate reuse), so
    # every log row past the lattice is a genuinely new schedule
    assert len(res.log) >= res.lattice_size
    assert len({(c.variant, c.vmem_cap, c.sched.ratios) for c in res.log}) \
        == len(res.log)


def test_coordinate_descent_refines_unbalanced_ratios():
    """A 3-way bundle with wildly unbalanced grids gets a fine-grained
    ratio vector outside the {1,2,4,grid-proportional} lattice."""
    eth, _, _ = ps.make_ethash_like(R_dag=65536, bm=512)   # grid 128
    hist, _, _ = ps.make_hist(R=2048, C=256, bm=64)        # grid 32
    blake, _, _ = ps.make_blake_like(R=4096, bm=512)       # grid 8
    ops = (eth, hist, blake)
    res = autotuner.search(ops)
    lattice = {s.ratios for s in ratio_candidates(ops)}
    cd_cands = [c for c in res.log if c.sched.ratios not in lattice]
    assert cd_cands, "coordinate descent explored nothing beyond the lattice"
    lattice_best, _ = _lattice_best(ops)
    assert res.best.est.t_hfused <= lattice_best * (1 + 1e-12)


# ---------------------------------------------------------------------------
# automatic block-shrink variants (the register-cap analogue)
# ---------------------------------------------------------------------------
def test_shrink_blocks_structural_rewrite_preserves_numerics():
    for make in (ps.make_maxpool, ps.make_upsample, ps.make_bnstats,
                 ps.make_sha_like):
        name = make.__name__.removeprefix("make_")
        op, mk, ref = make(**ps.SMALL_KW[name])
        s = op_spec.shrink_blocks(op, 2)
        assert s is not None, name
        assert s.grid == 2 * op.grid
        assert s.vmem_bytes < op.vmem_bytes
        x = mk(jax.random.PRNGKey(0))
        got = hfuse.run_single(s, interpret=True)(*x)
        want = ref(*x)
        want = want if isinstance(want, tuple) else (want,)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32),
                                       rtol=2e-3, atol=2e-3)


def test_shrink_blocks_rejects_body_coupled_ops():
    """ethash's seed block is added elementwise to the DAG block — halving
    one side would break the body; the rewrite must refuse."""
    eth, _, _ = ps.make_ethash_like(R_dag=512, bm=128)
    assert op_spec.shrink_blocks(eth, 2) is None


def test_shrink_blocks_honours_explicit_factory():
    op, _, _ = ps.make_maxpool(R=256, C=128, bm=64)
    marker, _, _ = ps.make_maxpool(R=256, C=128, bm=32)
    op.shrink = lambda f: marker
    assert op_spec.shrink_blocks(op, 2) is marker


def test_search_auto_generates_shrunk_variants_when_over_budget():
    """When 2*sum(vmem) blows the budget the search synthesizes halved-
    block variants itself — no caller-built variant lists — and the best
    candidate co-resides again."""
    a, _, _ = ps.make_maxpool(R=16384, C=4096, bm=4096)
    b, _, _ = ps.make_sha_like(R=16384, C=128, bm=4096)
    assert 2 * (a.vmem_bytes + b.vmem_bytes) > VMEM_BUDGET
    res = autotuner.search((a, b))
    assert any(c.variant > 0 for c in res.log), "no shrunk variants searched"
    assert res.best.est.vmem_ok
    assert res.best.variant > 0
    assert res.ops[0].grid > a.grid or res.ops[1].grid > b.grid


# ---------------------------------------------------------------------------
# persistent schedule cache
# ---------------------------------------------------------------------------
def test_schedule_cache_roundtrip_and_persistence(tmp_path):
    ops, _, _ = _bundle(("ethash_like", "hist", "blake_like"))
    path = tmp_path / "sched.json"
    cache = ScheduleCache(path)
    n0 = autotuner.SEARCH_COUNT
    r1 = autotuner.search(tuple(ops), cache=cache)
    assert autotuner.SEARCH_COUNT == n0 + 1 and not r1.cache_hit
    r2 = autotuner.search(tuple(ops), cache=cache)
    assert autotuner.SEARCH_COUNT == n0 + 1 and r2.cache_hit
    assert r2.best.sched == r1.best.sched
    assert r2.best.vmem_cap == r1.best.vmem_cap
    # a fresh process (new cache object, same file) still hits
    cache2 = ScheduleCache(path)
    r3 = autotuner.search(tuple(ops), cache=cache2)
    assert autotuner.SEARCH_COUNT == n0 + 1 and r3.cache_hit
    assert r3.best.sched == r1.best.sched


def test_bundle_signature_invalidation():
    ops, _, _ = _bundle(("maxpool", "sha_like"))
    base = bundle_signature(ops, vmem_budget=VMEM_BUDGET)
    assert base == bundle_signature(ops, vmem_budget=VMEM_BUDGET)
    assert base != bundle_signature(ops, vmem_budget=VMEM_BUDGET // 2)
    assert base != bundle_signature(ops, vmem_budget=VMEM_BUDGET,
                                    mode="interpret")
    bigger, _, _ = ps.make_bundle(("maxpool", "sha_like"))   # full-size ops
    assert base != bundle_signature(bigger, vmem_budget=VMEM_BUDGET)
    assert base != bundle_signature(ops[::-1], vmem_budget=VMEM_BUDGET)


def test_schedule_cache_survives_corrupt_file(tmp_path):
    path = tmp_path / "sched.json"
    path.write_text("{not json")
    cache = ScheduleCache(path)
    assert len(cache) == 0
    cache.put("k", {"ratios": [1, 1]})
    assert ScheduleCache(path).get("k") == {"ratios": [1, 1]}


def test_default_cache_resolves_env(tmp_path, monkeypatch):
    import repro.core.schedule_cache as sc
    monkeypatch.setenv("REPRO_SCHEDULE_CACHE", str(tmp_path / "c.json"))
    monkeypatch.setattr(sc, "_DEFAULT", None)
    c = default_cache()
    assert c.path == tmp_path / "c.json"
    assert default_cache() is c


# ---------------------------------------------------------------------------
# planner integration: memoized growth + zero re-search on repeat
# ---------------------------------------------------------------------------
def _graph():
    graph = []
    for f in (ps.make_ethash_like, ps.make_upsample, ps.make_sha_like,
              ps.make_blake_like):
        op, _, _ = f()
        graph.append(planner.GraphOp(op))
    return graph


def test_planner_repeat_plan_hits_cache_zero_searches(tmp_path):
    cache = ScheduleCache(tmp_path / "sched.json")
    p1 = planner.plan(_graph(), max_ways=3, cache=cache)
    n = autotuner.SEARCH_COUNT
    hits0 = cache.hits
    p2 = planner.plan(_graph(), max_ways=3, cache=cache)
    assert autotuner.SEARCH_COUNT == n, "repeat plan re-searched a bundle"
    assert cache.hits > hits0
    assert [d.members for d in p1.fused] == [d.members for d in p2.fused]
    assert [d.result.best.sched for d in p1.fused] == \
        [d.result.best.sched for d in p2.fused]


def test_planner_growth_memoizes_bundle_searches():
    """Bundle growth must not re-run a full search for a name-set it
    already scored (the O(n^2)-full-searches satellite)."""
    n0 = autotuner.SEARCH_COUNT
    planner.plan(_graph(), max_ways=3)
    spent = autotuner.SEARCH_COUNT - n0
    # 4 ops: <= C(4,2) pair seeds + growth candidates + finals; without the
    # memo the final search alone re-runs every grown bundle.  The exact
    # count is implementation detail — the bound is what the memo buys.
    assert spent <= 10, spent


def test_planner_measured_plan_reports_measured_speedup():
    measure = timing.make_measure("interpret")
    p = planner.plan(_graph(), max_ways=3, measure=measure)
    assert p.fused
    for d in p.fused:
        assert d.measured_speedup_pct is not None
        assert d.result.best.measured_s is not None
    # the interpret proxy is rank-only: it picks schedules but must NOT
    # gate admission (its absolute native-vs-fused gap is launch noise) —
    # bundle membership matches the cost-model plan
    p_cm = planner.plan(_graph(), max_ways=3)
    assert {d.members for d in p.fused} == {d.members for d in p_cm.fused}


def test_planner_measured_regression_rejects_bundle():
    """Measurement outranks the model for admission: a bundle the profiler
    shows losing vs native is rejected even if the cost model loves it."""
    def pessimist(fused, *ops):
        # fused kernels (have .schedule) measure slow; native measures fast
        return 1.0 if hasattr(fused, "schedule") else 1e-3
    pessimist.backend = "stub"

    p = planner.plan(_graph(), max_ways=3, measure=pessimist)
    assert not p.fused
    assert p.rejected
    assert all("measured" in reason for *_, reason in p.rejected)


def test_planner_measured_replan_profiles_nothing(tmp_path):
    """Replanning an unchanged graph with a cache performs zero searches
    AND zero profiling runs (native baseline rides in the cache entry)."""
    counted, calls = _counting(timing.make_measure("interpret"))
    cache = ScheduleCache(tmp_path / "sched.json")
    p1 = planner.plan(_graph(), max_ways=3, measure=counted, cache=cache)
    assert p1.fused and calls
    n_calls = len(calls)
    p2 = planner.plan(_graph(), max_ways=3, measure=counted, cache=cache)
    assert len(calls) == n_calls, "replan re-profiled a known bundle"
    assert [d.measured_speedup_pct for d in p2.fused] == \
        [d.measured_speedup_pct for d in p1.fused]


def test_schedule_cache_merges_concurrent_writers(tmp_path):
    path = tmp_path / "shared.json"
    c1, c2 = ScheduleCache(path), ScheduleCache(path)
    c1.put("a", {"ratios": [1]})
    c2.put("b", {"ratios": [2]})          # must not clobber c1's entry
    fresh = ScheduleCache(path)
    assert fresh.get("a") == {"ratios": [1]}
    assert fresh.get("b") == {"ratios": [2]}


def test_cache_entry_with_unresolvable_variant_is_a_miss(tmp_path):
    ops, _, _ = _bundle(("maxpool", "sha_like"))
    cache = ScheduleCache(tmp_path / "sched.json")
    res = autotuner.search(tuple(ops), cache=cache)
    cache.entries[res.cache_key]["variant"] = 99      # poisoned index
    res2 = autotuner.search(tuple(ops), cache=cache)
    assert not res2.cache_hit                          # fell back to search
    assert res2.best.variant < 99


def test_fusion_plan_summary_uniform_schema():
    p = planner.plan(_graph(), max_ways=3)
    keys = {"members", "schedule", "vmem_cap", "predicted_speedup_pct",
            "measured_speedup_pct"}
    rows = p.summary()
    assert rows
    assert all(set(r) == keys for r in rows)
    singles = [r for r in rows if r["schedule"] == "-"]
    for r in singles:
        assert r["vmem_cap"] is None and r["measured_speedup_pct"] is None


# ---------------------------------------------------------------------------
# train/serve wiring
# ---------------------------------------------------------------------------
def test_train_loop_plans_optimizer_backward_overlap():
    from repro.core.stitch import CHAIN_SEP
    from repro.train.train_loop import plan_update_fusion
    params = {
        "wqkv": jax.ShapeDtypeStruct((2048, 2048), jax.numpy.bfloat16),
        "wff": jax.ShapeDtypeStruct((2048, 8192), jax.numpy.bfloat16),
        # an embedding-scale 1-D leaf: the memory-bound seed whose update
        # hides behind another tensor's compute-bound backward chain
        "embed": jax.ShapeDtypeStruct((4194304,), jax.numpy.bfloat16),
    }
    plan = plan_update_fusion(params, tokens=4096, max_ways=3)
    # each 2-D tensor's dW matmul stitched its OWN update as an epilogue
    # (the gradient never round-trips HBM) ...
    members = [m for d in plan.fused for m in d.members] + list(plan.singles)
    assert f"dW_wqkv{CHAIN_SEP}adamw_wqkv" in members
    assert f"dW_wff{CHAIN_SEP}adamw_wff" in members
    # ... and the horizontal overlap still happens ON TOP: the embedding's
    # memory-bound update rides a stitched backward chain
    assert plan.fused, "optimizer/backward overlap found no bundle"
    assert any(any(CHAIN_SEP in m for m in d.members)
               and any(CHAIN_SEP not in m for m in d.members)
               for d in plan.fused), \
        "no bundle mixes a stitched chain with a plain update"
    for d in plan.fused:
        names = set(d.members)
        # an update never fuses HORIZONTALLY with the dW matmul producing
        # its grad — that pairing is the vertical stitch, one member
        for n in names:
            if n.startswith("adamw_"):
                assert f"dW_{n.removeprefix('adamw_')}" not in names


def test_serve_engine_plans_decode_bundle():
    from repro.configs import get_config
    from repro.serve.engine import PrefillBudget, ServeEngine

    cfg = get_config("granite-3-2b")          # full dims: the flash-prefill
    eng = ServeEngine.__new__(ServeEngine)    # chunk is compute-bound, the
    eng.cfg, eng.batch, eng.max_len = cfg, 16, 4096   # paper bundle forms
    eng.prefill_budget = PrefillBudget()
    plan = eng.plan_decode_fusion()
    assert plan.fused, "decode-step plan found no profitable bundle"
    for d in plan.fused:
        if any(m.startswith("decode_attn") for m in d.members):
            assert any(m.startswith("prefill_attn") for m in d.members), \
                "decode attention paired with no prefill chunk"
            break
    else:
        raise AssertionError("no bundle contains decode attention")


@pytest.mark.parametrize("max_len", [1100, 1536, 2047, 640])
def test_serve_plan_handles_unaligned_max_len(max_len):
    """ck must divide the 128-aligned cache length for ANY max_len."""
    from repro.configs import get_config
    from repro.serve.engine import ServeEngine

    from repro.serve.engine import PrefillBudget

    eng = ServeEngine.__new__(ServeEngine)
    eng.cfg, eng.batch, eng.max_len = get_config("granite-3-2b"), 8, max_len
    eng.prefill_budget = PrefillBudget()
    assert eng.plan_decode_fusion(max_ways=3).summary()
