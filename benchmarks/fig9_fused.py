"""Paper Fig. 9: fused-kernel metrics with and without the resource cap.

The register bound maps to the VMEM working-set control (DESIGN.md §2):
  N-RegCap — large MXU-efficient blocks; the fused pair may exceed the
             double-buffered VMEM budget -> pipelining degrades (the
             occupancy cliff; overlap_eff < 100, speedup can go negative,
             exactly the paper's Blake256+Blake2B -96.5% pathology).
  RegCap   — blocks halved until the pair co-resides (the paper's
             computed register bound r0): occupancy recovered at a small
             per-block efficiency cost (modeled via the ramp term).

Reported per pair at the representative (ratio≈1) workload.
"""
from __future__ import annotations

from benchmarks.common import csv_row
from repro.core import autotuner
from repro.core.cost_model import VMEM_BUDGET, native_time
from repro.kernels import paper_suite as ps

# wide-tensor configs that create genuine VMEM pressure when fused
BIG = dict(
    maxpool=dict(R=16384, C=2048, bm=2048),
    bnstats=dict(R=16384, C=2048, bm=2048),
    upsample=dict(R=8192, C=2048, bm=2048),
    im2col=dict(R=8192, C=2048, bm=1024),
    hist=dict(R=8192, C=1024, bm=64),
    ethash_like=dict(R_dag=262144, bm=4096),
    sha_like=dict(R=16384, bm=2048),
    blake_like=dict(R=16384, bm=2048),
    blake2b_like=dict(R=16384, bm=2048),
)


def halved(name):
    kw = dict(BIG[name])
    kw["bm"] = max(32, kw["bm"] // 4)
    return kw


def run():
    csv_row("pair", "type", "speedup_pct", "overlap_eff_pct",
            "vmem_mb", "fits", "sched")
    for a_name, b_name in ps.paper_pairs():
        for typ, mk in (("N-RegCap", BIG), ("RegCap", None)):
            kwa = BIG[a_name] if typ == "N-RegCap" else halved(a_name)
            kwb = BIG[b_name] if typ == "N-RegCap" else halved(b_name)
            opA, _, _ = ps.ALL_KERNELS[a_name](**kwa)
            opB, _, _ = ps.ALL_KERNELS[b_name](**kwb)
            res = autotuner.search((opA, opB))
            est = res.best.est
            csv_row(f"{a_name}+{b_name}", typ,
                    round(est.speedup_pct(), 1),
                    round(100 * est.overlap_eff, 1),
                    round(est.vmem_bytes / 2 ** 20, 1), est.vmem_ok,
                    f"{res.best.sched.ra}:{res.best.sched.rb}")


if __name__ == "__main__":
    run()
