"""Parameter-spec system.

A model is described by a *spec tree*: a nested dict whose leaves are
``ParamSpec(shape, axes, init)``.  From one spec tree we derive, guaranteed
consistent with each other:

  * concrete parameters          (``init_params``)
  * abstract parameters          (``abstract_params`` — ShapeDtypeStructs,
                                  used by the dry-run: no allocation)
  * logical partition specs      (``logical_axes`` — resolved to mesh axes by
                                  ``repro.distributed.sharding``)

Logical axis vocabulary (resolved per-family in distributed/sharding.py):
  "embed"   d_model dim            "ffn"     MLP hidden dim
  "heads"   query heads            "kv_heads" kv heads
  "qkv"     fused q/k/v output     "vocab"   vocabulary
  "expert"  MoE expert count       "layer"   stacked scan dim
  "lru"     recurrent width        None      replicated
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones | embed | out_proj
    dtype: Optional[str] = None     # overrides model dtype (e.g. fp32 gate biases)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


SpecTree = dict  # nested dict[str, SpecTree | ParamSpec]


def _fan_in(shape: tuple[int, ...]) -> int:
    # weight matrices here are (in, out) or (..., in, out)
    return shape[-2] if len(shape) >= 2 else shape[-1]


def _init_leaf(spec: ParamSpec, key: jax.Array, dtype) -> jax.Array:
    dt = jnp.dtype(spec.dtype) if spec.dtype else dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        scale = 1.0
    elif spec.init == "out_proj":
        scale = 1.0 / math.sqrt(2.0 * max(1, _fan_in(spec.shape)))
    else:
        scale = 1.0 / math.sqrt(max(1, _fan_in(spec.shape)))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dt)


def _tree_map_with_key(fn: Callable, tree: SpecTree, key: jax.Array):
    """Map fn(spec, key) over leaves with independent, deterministic keys."""
    leaves = []

    def walk(t, path):
        if isinstance(t, ParamSpec):
            leaves.append((path, t))
        else:
            for k in sorted(t):
                walk(t[k], path + (k,))

    walk(tree, ())
    keys = jax.random.split(key, max(1, len(leaves)))
    out: dict = {}
    for (path, spec), k in zip(leaves, keys):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = fn(spec, k)
    return out


def init_params(specs: SpecTree, key: jax.Array, dtype=jnp.bfloat16):
    return _tree_map_with_key(lambda s, k: _init_leaf(s, k, dtype), specs, key)


def abstract_params(specs: SpecTree, dtype=jnp.bfloat16):
    def mk(s: ParamSpec, _k):
        dt = jnp.dtype(s.dtype) if s.dtype else dtype
        return jax.ShapeDtypeStruct(s.shape, dt)
    return _tree_map_with_key(mk, specs, jax.random.PRNGKey(0))


def logical_axes(specs: SpecTree):
    def mk(s: ParamSpec, _k):
        return s.axes
    return _tree_map_with_key(mk, specs, jax.random.PRNGKey(0))


def stack_specs(specs: SpecTree, n: int) -> SpecTree:
    """Prepend a stacked 'layer' dim to every leaf (for lax.scan runs)."""
    def walk(t):
        if isinstance(t, ParamSpec):
            return ParamSpec((n,) + t.shape, ("layer",) + t.axes, t.init, t.dtype)
        return {k: walk(v) for k, v in t.items()}
    return walk(specs)


def count_spec_params(specs: SpecTree) -> int:
    total = 0

    def walk(t):
        nonlocal total
        if isinstance(t, ParamSpec):
            total += int(np.prod(t.shape))
        else:
            for v in t.values():
                walk(v)

    walk(specs)
    return total


# ---------------------------------------------------------------------------
# Common spec builders
# ---------------------------------------------------------------------------
def dense_spec(d_in: int, d_out: int, axes=( "embed", "ffn"), init="normal") -> ParamSpec:
    return ParamSpec((d_in, d_out), axes, init)
