"""Generate() — build the horizontally-fused Pallas kernel from two OpSpecs.

This is the TPU realization of the paper's Fig. 5 algorithm:

  paper (CUDA thread space)             here (Pallas grid space)
  -------------------------------------------------------------------------
  threads [0,d1) run K1, [d1,d0) K2     grid steps interleave A/B per the
                                        Schedule (ra A-steps : rb B-steps)
  branch on threadIdx.x                 @pl.when(phase(program_id))
  replace threadIdx/blockDim with       op-local step s_A(t), s_B(t) passed
  tid_1/size_1, tid_2/size_2            to each body
  bar.sync id, d partial barriers       not needed: grid steps independent
                                        (see DESIGN.md §2)
  register cap (maxrregcount)           VMEM cap via block-shape choice +
                                        compiler vmem limit

DMA-elision scheduling: during B's phase, every A operand's index map *holds*
its last value (Pallas skips the copy when the block index is unchanged
between steps), and vice versa.  Thus while a compute-bound B step occupies
the MXU, the pipeline prefetches A's next (memory-bound) blocks — the warp-
scheduler latency hiding of the paper, reconstructed with the only
latency-hiding machinery a TPU has.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cost_model import Schedule
from repro.core.op_spec import OpSpec


def _phase_fns(a: OpSpec, b: OpSpec, sched: Schedule):
    ra, rb, period = sched.ra, sched.rb, sched.period

    def a_step(t):
        s, ph = t // period, t % period
        idx = s * ra + jnp.minimum(ph, ra - 1)
        return jnp.clip(idx, 0, a.grid - 1)

    def a_active(t):
        s, ph = t // period, t % period
        return (ph < ra) & (s * ra + ph < a.grid)

    def b_step(t):
        s, ph = t // period, t % period
        idx = jnp.where(ph >= ra, s * rb + (ph - ra), s * rb - 1)
        return jnp.clip(idx, 0, b.grid - 1)

    def b_active(t):
        s, ph = t // period, t % period
        return (ph >= ra) & (s * rb + (ph - ra) < b.grid)

    n_super = max(math.ceil(a.grid / ra), math.ceil(b.grid / rb))
    return a_step, a_active, b_step, b_active, n_super * period


def generate(a: OpSpec, b: OpSpec, sched: Schedule, *,
             interpret: bool = False, vmem_limit: Optional[int] = None):
    """Returns fused(*a_inputs, *b_inputs) -> (*a_outputs, *b_outputs)."""
    a_step, a_active, b_step, b_active, n_steps = _phase_fns(a, b, sched)

    nia, noa = len(a.inputs), len(a.outputs)
    nib, nob = len(b.inputs), len(b.outputs)

    def fused_kernel(*refs):
        t = pl.program_id(0)
        a_in = refs[:nia]
        b_in = refs[nia: nia + nib]
        a_out = refs[nia + nib: nia + nib + noa]
        b_out = refs[nia + nib + noa:]

        @pl.when(a_active(t))
        def _():
            a.body(a_step(t), *a_in, *a_out)

        @pl.when(b_active(t))
        def _():
            b.body(b_step(t), *b_in, *b_out)

    def remap(op_step, operand):
        return pl.BlockSpec(operand.block_shape,
                            lambda t, _f=operand.index_map, _s=op_step: _f(_s(t)))

    in_specs = ([remap(a_step, o) for o in a.inputs]
                + [remap(b_step, o) for o in b.inputs])
    out_specs = ([remap(a_step, o) for o in a.outputs]
                 + [remap(b_step, o) for o in b.outputs])
    out_shape = ([jax.ShapeDtypeStruct(o.shape, o.dtype) for o in a.outputs]
                 + [jax.ShapeDtypeStruct(o.shape, o.dtype) for o in b.outputs])

    kwargs = {}
    if vmem_limit and not interpret and jax.default_backend() == "tpu":
        try:
            from jax.experimental.pallas import tpu as pltpu
            kwargs["compiler_params"] = pltpu.CompilerParams(
                vmem_limit_bytes=int(vmem_limit))
        except Exception:
            pass

    call = pl.pallas_call(
        fused_kernel,
        grid=(n_steps,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        **kwargs,
    )

    def fused(*operands):
        assert len(operands) == nia + nib, (len(operands), nia, nib)
        outs = call(*operands)
        return tuple(outs) if isinstance(outs, (list, tuple)) else (outs,)

    fused.n_steps = n_steps
    fused.schedule = sched
    return fused


def generate_vfused(a: OpSpec, b: OpSpec, **kw):
    """Concatenated (vertical-style) baseline: all A steps, then all B steps —
    one kernel, no interleaving.  Same machinery, degenerate schedule."""
    return generate(a, b, Schedule(a.grid, b.grid), **kw)


def run_single(op: OpSpec, *, interpret: bool = False):
    """Standalone pallas_call for one OpSpec (used by tests and `native`)."""
    def kernel(*refs):
        t = pl.program_id(0)
        op.body(t, *refs)

    call = pl.pallas_call(
        kernel,
        grid=(op.grid,),
        in_specs=[pl.BlockSpec(o.block_shape, o.index_map) for o in op.inputs],
        out_specs=[pl.BlockSpec(o.block_shape, o.index_map) for o in op.outputs],
        out_shape=[jax.ShapeDtypeStruct(o.shape, o.dtype) for o in op.outputs],
        interpret=interpret,
    )

    def run(*operands):
        outs = call(*operands)
        return tuple(outs) if isinstance(outs, (list, tuple)) else (outs,)
    return run


def run_native(a: OpSpec, b: OpSpec, *, interpret: bool = False):
    """The 'native' baseline: two separate pallas_calls (two launches).

    NOTE: on a TPU core there is no stream concurrency — two kernels
    serialize — which is why horizontal fusion is the *only* way two ops
    co-execute (DESIGN.md §8.5)."""
    def one(op):
        def kernel(*refs):
            t = pl.program_id(0)
            op.body(t, *refs)
        return pl.pallas_call(
            kernel,
            grid=(op.grid,),
            in_specs=[pl.BlockSpec(o.block_shape, o.index_map) for o in op.inputs],
            out_specs=[pl.BlockSpec(o.block_shape, o.index_map) for o in op.outputs],
            out_shape=[jax.ShapeDtypeStruct(o.shape, o.dtype) for o in op.outputs],
            interpret=interpret,
        )

    ca, cb = one(a), one(b)

    def native(*operands):
        outs_a = ca(*operands[:len(a.inputs)])
        outs_b = cb(*operands[len(a.inputs):])
        outs_a = outs_a if isinstance(outs_a, (list, tuple)) else [outs_a]
        outs_b = outs_b if isinstance(outs_b, (list, tuple)) else [outs_b]
        return (*outs_a, *outs_b)

    return native
