"""RecurrentGemma-2B — Griffin hybrid: RG-LRU recurrent blocks + local attention, 1:2
[arXiv:2402.19427; hf:google/recurrentgemma-2b]

26 layers, pattern (recurrent, recurrent, local-attn) repeating; MQA (kv=1),
GeGLU FFN 7680, d_model 2560, 10 heads (head_dim 256), vocab 256000,
local attention window 2048, logit softcap 30.
"""
from repro.configs.base import ModelConfig, RGLRU, LOCAL_ATTN, register


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    # 26 = 8 * (rec, rec, attn) + (rec, rec)
    pattern = tuple(([RGLRU, RGLRU, LOCAL_ATTN] * 9)[:26])
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        block_pattern=pattern,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        activation="gelu",          # GeGLU
        norm="rmsnorm",
        local_window=2048,
        lru_width=2560,
        conv1d_width=4,
        logit_softcap=30.0,
        tie_embeddings=True,
        source="[arXiv:2402.19427; hf] RG-LRU + local attn 1:2",
    )
