from repro.configs.base import (  # noqa: F401
    ATTN, LOCAL_ATTN, MLA, MLSTM, RGLRU, SLSTM, SHAPES,
    MLAConfig, MoEConfig, ModelConfig, ShapeConfig,
    get_config, list_archs, register, shape_applicable,
)
