"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Two execution paths:
  * train/prefill — expanded: latent is up-projected to per-head K/V and fed
    through the shared blockwise flash attention.
  * decode — *absorbed*: W_UK is folded into the query and W_UV into the
    output so attention runs directly against the (kv_lora + rope) latent
    cache.  The KV cache is (B, S, 512+64) instead of (B, S, H, 192+128):
    a ~47x cache-byte reduction — this is the memory-bound side that pairs
    with MoE expert compute in the horizontal-fusion planner (DESIGN.md §4).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.base import ParamSpec


def spec(cfg) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_q_a": ParamSpec((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_norm": layers.rmsnorm_spec(m.q_lora_rank),
        "w_q_b": ParamSpec((m.q_lora_rank, H * qk), ("q_lora", "qkv")),
        "w_kv_a": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                            ("embed", "kv_lora")),
        "kv_norm": layers.rmsnorm_spec(m.kv_lora_rank),
        "w_k_b": ParamSpec((m.kv_lora_rank, H * m.qk_nope_head_dim),
                           ("kv_lora", "qkv")),
        "w_v_b": ParamSpec((m.kv_lora_rank, H * m.v_head_dim),
                           ("kv_lora", "qkv")),
        "w_o": ParamSpec((H * m.v_head_dim, d), ("qkv", "embed"), "out_proj"),
    }


def _project_q(cfg, p, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = layers.rmsnorm(p["q_norm"], x @ p["w_q_a"]) @ p["w_q_b"]
    q = q.reshape(B, S, H, qk)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = layers.rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(cfg, p, x, positions):
    m = cfg.mla
    kv = x @ p["w_kv_a"]
    latent = layers.rmsnorm(p["kv_norm"], kv[..., : m.kv_lora_rank])
    k_rope = kv[..., m.kv_lora_rank:][:, :, None, :]          # one shared head
    k_rope = layers.rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return latent, k_rope


def attend_full(cfg, p, x, positions):
    """Expanded path (train / prefill)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _project_q(cfg, p, x, positions)
    latent, k_rope = _project_kv_latent(cfg, p, x, positions)
    k_nope = (latent @ p["w_k_b"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (latent @ p["w_v_b"]).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    o = layers.blockwise_attention(q, k, v, causal=True)
    return o.reshape(B, S, H * m.v_head_dim) @ p["w_o"], (latent, k_rope)


def attend_absorbed(cfg, p, x, latent_cache, rope_cache, pos, positions):
    """Absorbed decode path: score/readout directly in latent space.

    latent_cache: (B, Smax, kv_lora); rope_cache: (B, Smax, rope_dim);
    pos: () int32 index of the generated token.  The new latent is written at
    ``pos`` *before* attending so the token attends to itself.
    Returns (out (B,1,d), new_latent_cache, new_rope_cache).
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    q_nope, q_rope = _project_q(cfg, p, x, positions)      # (B,1,H,·)
    latent_t, rope_t = _project_kv_latent(cfg, p, x, positions)
    latent_cache = jax.lax.dynamic_update_slice(latent_cache, latent_t, (0, pos, 0))
    rope_cache = jax.lax.dynamic_update_slice(rope_cache, rope_t, (0, pos, 0))
    cur_len = pos + 1

    w_k_b = p["w_k_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    # absorb W_UK into q:  (B,1,H,nope) x (k,H,nope) -> (B,H,k)
    q_lat = jnp.einsum("bshn,khn->bhk", q_nope, w_k_b)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (jnp.einsum("bhk,bsk->bhs", q_lat, latent_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                      rope_cache.astype(jnp.float32))
         ) * scale
    valid = jnp.arange(latent_cache.shape[1])[None, None, :] < cur_len
    s = jnp.where(valid, s, layers.NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsk->bhk", w.astype(latent_cache.dtype),
                         latent_cache)
    w_v_b = p["w_v_b"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bhk,khv->bhv", ctx_lat, w_v_b).reshape(B, 1, H * m.v_head_dim)
    return o @ p["w_o"], latent_cache, rope_cache
