"""Chunked flash prefill: the PrefillBudget API, the blockwise
prefill-attention kernel, and the chunk-granular continuous engine.

Differential contract: with a small ``chunk_rows`` budget, prompts spanning
1, 2, and 5+ chunks are chipped away across iterations and the executed
engine stays token-for-token identical to the wavefront oracle (which
prefills whole prompts in one shot) — including mid-batch EOS retirement.
Structural contract: ``Program.fused_members`` shows every prefill chunk
co-resident with decode-side work — one with decode attention, one with the
stitched ``ffn_proj→decode_act`` epilogue chain.  Plus: the kernel's
online-softmax numerics vs a dense jnp reference at nonzero chunk offsets,
``reject_overlong=True`` restoring the legacy admission contract, and
DeprecationWarnings on the prefill_rows/prefill_chunk/pad_prefill_rows
aliases the budget replaced."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hfuse
from repro.core.stitch import CHAIN_SEP
from repro.kernels.prefill_attention import prefill_attention_op
from repro.models import lm
from repro.serve.engine import (PrefillBudget, Request, ServeEngine,
                                pad_prefill_rows)


def _cfg():
    return dataclasses.replace(get_config("granite-3-2b").reduced(),
                               dtype="float32")


# Prompt lengths span 1, 2, and 6 chunks at chunk_rows=8 (cache 128 ->
# effective chunk 8); budgets staggered so slots retire mid-run.
CHUNKED_LENS = (6, 15, 41)
CHUNKED_BUDGETS = (3, 4, 3)
BUDGET = PrefillBudget(chunk_rows=8, max_coresident_chunks=2)


def _requests(cfg, lens, budgets, eos=None, seed=11):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, L).astype(np.int32),
                    max_new_tokens=m, eos_token=eos)
            for i, (L, m) in enumerate(zip(lens, budgets))]


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    wave = ServeEngine(cfg, params, batch=2, max_len=48,
                       scheduling="wavefront")
    chunked = ServeEngine(cfg, params, batch=2, max_len=48,
                          scheduling="continuous", plan_fusion=True,
                          prefill_budget=BUDGET)
    assert chunked.executed, "reduced granite must support the executed path"
    return cfg, params, wave, chunked


# ---------------------------------------------------------------------------
# PrefillBudget unit contract
# ---------------------------------------------------------------------------
def test_budget_validates():
    for bad in (dict(chunk_rows=0), dict(max_coresident_chunks=0),
                dict(pad_to=-1)):
        with pytest.raises(ValueError, match="must be >= 1"):
            PrefillBudget(**bad)
    with pytest.raises(ValueError, match="policy"):
        PrefillBudget(policy="lifo")


def test_srpf_policy_lowers_admission_latency(setup):
    """Shortest-remaining-prefill-first: with one chunk of budget per step
    and a short prompt queued behind a long one, FIFO makes the short
    prompt wait out the long prefill's tail; SRPF admits it first.  Token
    streams stay identical to the wavefront oracle either way."""
    cfg, params, wave, _chunked = setup
    lens, buds = (41, 6), (3, 3)          # 6-chunk prompt, then a 1-chunk
    ref = _requests(cfg, lens, buds)
    wave.run(ref)
    stats = {}
    for policy in ("fifo", "srpf"):
        eng = ServeEngine(
            cfg, params, batch=2, max_len=48, scheduling="continuous",
            plan_fusion=True,
            prefill_budget=dataclasses.replace(
                BUDGET, max_coresident_chunks=1, policy=policy))
        rs = _requests(cfg, lens, buds)
        eng.run(rs)
        assert [r.out_tokens for r in rs] == [r.out_tokens for r in ref], \
            f"{policy} diverged from the wavefront oracle"
        stats[policy] = eng.stats
    assert (stats["srpf"].mean_admission_latency
            < stats["fifo"].mean_admission_latency), (
        stats["srpf"].admission_latencies,
        stats["fifo"].admission_latencies)


def test_budget_effective_chunk_divides_cache():
    assert PrefillBudget(chunk_rows=8).effective_chunk(128) == 8
    assert PrefillBudget(chunk_rows=2048).effective_chunk(128) == 128
    # rounds down to a divisor so chunk offsets stay chunk-aligned
    assert PrefillBudget(chunk_rows=24).effective_chunk(128) == 16
    assert PrefillBudget(chunk_rows=7).effective_chunk(128) == 4
    for rows, cache in ((8, 128), (24, 128), (100, 384)):
        c = PrefillBudget(chunk_rows=rows).effective_chunk(cache)
        assert c <= rows and cache % c == 0


def test_budget_effective_chunk_ragged_cache_lengths():
    """Direct largest-divisor computation (no O(cache_len) scan): exact on
    ragged cache lengths — primes, prime powers, highly-composite — and on
    the paged form, where the chunk must ALSO be a multiple of the KV block
    size so every chunk is a whole number of pages."""
    for rows, cache in ((8, 127), (50, 121), (36, 360), (17, 97),
                        (1, 4096), (5000, 3600), (64, 2 * 3 * 5 * 7 * 11)):
        got = PrefillBudget(chunk_rows=rows).effective_chunk(cache)
        brute = max(d for d in range(1, min(rows, cache) + 1)
                    if cache % d == 0)
        assert got == brute, (rows, cache, got, brute)
    # multiple=: chunk is the largest divisor of cache that is BOTH a
    # multiple of `multiple` and <= chunk_rows (floored up to `multiple`)
    for rows, cache, mult in ((8, 128, 16), (48, 96, 16), (40, 320, 8),
                              (16, 256, 16), (9, 144, 4)):
        got = PrefillBudget(chunk_rows=rows).effective_chunk(cache, mult)
        cands = [d for d in range(mult, cache + 1, mult)
                 if cache % d == 0 and d <= max(rows, mult)]
        assert got == (max(cands) if cands else mult), \
            (rows, cache, mult, got)
        assert got % mult == 0 and cache % got == 0
    with pytest.raises(ValueError, match="multiple"):
        PrefillBudget(chunk_rows=8).effective_chunk(100, 16)


def test_budget_pad_rows():
    b = PrefillBudget(pad_to=128)
    assert b.pad_rows(7) == 7            # raw below one tile
    assert b.pad_rows(128) == 128
    assert b.pad_rows(129) == 256        # next tile multiple beyond


# ---------------------------------------------------------------------------
# Kernel numerics: blockwise online softmax vs dense reference
# ---------------------------------------------------------------------------
def _ref_attn(q, k, v, off):
    C, H, D = q.shape
    S, Hkv, _ = k.shape
    rep = H // Hkv
    qf = q.astype(jnp.float32) / math.sqrt(D)
    s = jnp.einsum("chrd,khd->chrk", qf.reshape(C, Hkv, rep, D),
                   k.astype(jnp.float32))
    kpos = jnp.arange(S)[None, None, None, :]
    qpos = off + jnp.arange(C)[:, None, None, None]
    s = jnp.where(kpos <= qpos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("chrk,khd->chrd", p, v.astype(jnp.float32))
    return o.reshape(C, H, D)


@pytest.mark.parametrize("C,S,Hkv,ck,off", [
    (8, 64, 2, 16, 0),       # multi-block grid, prefix-free chunk
    (8, 64, 2, 16, 23),      # chunk in the middle of a prefix (GQA rep=2)
    (8, 128, 4, 128, 40),    # grid-1: whole cache in one k/v block
    (5, 128, 4, 128, 0),     # ragged chunk rows (C below the lane tile)
])
def test_prefill_kernel_matches_reference(C, S, Hkv, ck, off):
    H, D = 4, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(C, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(S, Hkv, D)), jnp.float32)
    op = prefill_attention_op(C, S, H, Hkv, D, dtype=jnp.float32, ck=ck)
    offa = jnp.full((1, 1), off, jnp.int32)
    o, _m, _l = hfuse.run_single(op, interpret=True)(offa, q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(_ref_attn(q, k, v, off)),
                               atol=1e-4, rtol=1e-4)


def test_prefill_op_shrinks_blockwise():
    op = prefill_attention_op(8, 128, 4, 4, 16, dtype=jnp.float32, ck=64)
    small = op.shrink(2)
    assert small is not None and small.grid == 4      # ck 64 -> 32
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(8, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(128, 4, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(128, 4, 16)), jnp.float32)
    offa = jnp.full((1, 1), 16, jnp.int32)
    o_big, *_ = hfuse.run_single(op, interpret=True)(offa, q, k, v)
    o_small, *_ = hfuse.run_single(small, interpret=True)(offa, q, k, v)
    np.testing.assert_allclose(np.asarray(o_small), np.asarray(o_big),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Structural: the hybrid mixed-iteration program — every prefill chunk rides
# a fused launch with decode-side work, and one of those partners is a
# stitched epilogue chain (vertical fusion INSIDE the horizontal bundle)
# ---------------------------------------------------------------------------
def test_program_fuses_chunks_with_decode_side_work(setup):
    _cfg_, _params, _wave, chunked = setup
    prog = chunked.build_decode_program(prefill_chunks=2)
    fused = prog.fused_members
    mixed = [ms for ms in fused
             if any(m.startswith("prefill_attn") for m in ms)
             and any(not m.startswith("prefill_attn") for m in ms)]
    # both chunks co-reside with decode-side work
    chunks_fused = {m for ms in mixed for m in ms
                    if m.startswith("prefill_attn")}
    assert len(chunks_fused) == 2, f"chunk not fused with decode: {fused}"
    # decode attention carries a chunk (the paper's heterogeneous pairing)
    assert any(any(m.startswith("decode_attn") for m in ms)
               for ms in mixed), f"decode attention rides alone: {fused}"
    # and a stitched producer→consumer chain rides a mixed launch too
    assert any(any(CHAIN_SEP in m for m in ms) for ms in mixed), \
        f"no stitched chain inside a mixed launch: {fused}"


# ---------------------------------------------------------------------------
# Differential: chunked admission == wavefront oracle, token for token
# ---------------------------------------------------------------------------
def test_chunked_matches_wavefront(setup):
    cfg, _params, wave, chunked = setup
    rw = _requests(cfg, CHUNKED_LENS, CHUNKED_BUDGETS)
    rc = _requests(cfg, CHUNKED_LENS, CHUNKED_BUDGETS)
    wave.run(rw)
    chunked.run(rc)
    assert [r.out_tokens for r in rc] == [r.out_tokens for r in rw]
    st = chunked.stats
    # every prompt admitted chunk-by-chunk: 1 + 2 + 6 chunks of 8 rows
    assert st.prefill_chunks == sum(-(-L // 8) for L in CHUNKED_LENS)
    # the 41-token prompt needed >= 2 iterations of chipping (6 chunks,
    # one per iteration while its slot prefills)
    assert max(st.admission_latencies) >= 5
    assert st.mixed_steps > 0, "no chunk ever rode a decode step"
    assert st.fused_prefill_fraction > 0.0
    assert st.tokens == sum(len(r.out_tokens) for r in rc)


def test_chunked_eos_finishes_mid_batch(setup):
    cfg, _params, wave, chunked = setup
    probe = _requests(cfg, CHUNKED_LENS, CHUNKED_BUDGETS)
    wave.run(probe)
    eos = probe[1].out_tokens[1]          # fires after 2 of its 4 tokens
    rw = _requests(cfg, CHUNKED_LENS, CHUNKED_BUDGETS, eos=eos)
    rc = _requests(cfg, CHUNKED_LENS, CHUNKED_BUDGETS, eos=eos)
    wave.run(rw)
    chunked.run(rc)
    assert [r.out_tokens for r in rc] == [r.out_tokens for r in rw]
    assert any(reason == "eos" for _s, _r, reason
               in chunked.stats.retirements)
    assert len(rc[1].out_tokens) < CHUNKED_BUDGETS[1]


def test_reject_overlong_restores_legacy_contract(setup):
    cfg, params, _wave, _chunked = setup
    eng = ServeEngine(cfg, params, batch=2, max_len=48,
                      scheduling="continuous", prefill_budget=BUDGET,
                      reject_overlong=True)
    ok = _requests(cfg, (6,), (2,))
    eng.run(ok)                           # one chunk: still admitted
    assert len(ok[0].out_tokens) == 2
    bad = _requests(cfg, (15,), (2,))
    with pytest.raises(ValueError, match="per-iteration prefill budget"):
        eng.run(bad)


# ---------------------------------------------------------------------------
# Deprecated aliases still work, loudly
# ---------------------------------------------------------------------------
def test_pad_prefill_rows_alias_warns():
    with pytest.warns(DeprecationWarning, match="PrefillBudget.pad_rows"):
        assert pad_prefill_rows(129) == PrefillBudget().pad_rows(129) == 256


def test_decode_graph_prefill_rows_alias_warns(setup):
    _cfg_, _params, _wave, chunked = setup
    with pytest.warns(DeprecationWarning, match="prefill_rows"):
        graph = chunked.decode_graph(prefill_rows=128)
    assert any(g.op.name == "prefill_ffn" for g in graph)


def test_plan_decode_fusion_prefill_chunk_alias_warns(setup):
    _cfg_, _params, _wave, chunked = setup
    with pytest.warns(DeprecationWarning, match="prefill_chunk"):
        plan = chunked.plan_decode_fusion(prefill_chunk=8)
    names = [m for d in plan.fused for m in d.members] + list(plan.singles)
    assert any(n.startswith("prefill_attn") for n in names)


def test_build_decode_program_prefill_rows_alias_warns(setup):
    _cfg_, _params, _wave, chunked = setup
    with pytest.warns(DeprecationWarning, match="prefill_rows"):
        prog = chunked.build_decode_program(prefill_rows=128)
    assert any(any(m == "prefill_ffn" for m in s.members)
               for s in prog.steps)
