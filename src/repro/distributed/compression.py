"""Gradient compression for slow (inter-pod) links.

``pod_compressed_grads`` wraps the whole value-and-grad computation in a
``shard_map`` that is *manual* over the ``pod`` axis and *auto* over
(data, model): each pod computes gradients for its local batch half with the
normal SPMD partitioning inside, then gradients cross the slow inter-pod ICI
as **int8 + per-tensor scale** via all_gather (1 byte/elem on the wire vs 4),
and are dequantized+averaged locally.  Error feedback (the int8 residual is
carried in optimizer-adjacent state) keeps the compression unbiased over
time [1-bit Adam / EF-SGD lineage].

Off-mesh (no 'pod' axis) or compression=None, this degrades to plain
autodiff with the implicit psum.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map


def quantize_int8(x: jax.Array):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_roundtrip(g: jax.Array, residual: Optional[jax.Array] = None):
    """Quantize→dequantize with error feedback.  Returns (g_hat, new_residual)."""
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    q, scale = quantize_int8(gf)
    g_hat = dequantize_int8(q, scale)
    return g_hat.astype(g.dtype), (gf - g_hat)


def compressed_allgather_mean(g: jax.Array, axis_name: str) -> jax.Array:
    """int8 all_gather + local dequant/mean across ``axis_name`` (manual axis)."""
    q, scale = quantize_int8(g)
    qs = jax.lax.all_gather(q, axis_name)            # (n, ...) int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)        # (n,) fp32 (negligible)
    n = qs.shape[0]
    deq = qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * (qs.ndim - 1))
    return jnp.mean(deq, axis=0).astype(g.dtype)


def pod_compressed_grads(loss_fn: Callable, mesh: Mesh):
    """Returns grad_fn(params, batch) -> (loss, aux, grads) where the pod-axis
    gradient reduction crosses the inter-pod links as int8.

    loss_fn(params, batch) -> (loss, aux).  The shard_map is *manual* over
    'pod' only (``axis_names={'pod'}``); (data, model) stay auto —
    SPMD-partitioned as usual inside the body."""
    if "pod" not in mesh.axis_names:
        def plain(params, batch):
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return l, aux, g
        return plain

    def local_grads(params, batch):
        # inside the pod-manual region the model's sharding constraints must
        # not mention 'pod': re-enter the ambient context with pod stripped.
        from repro.distributed import sharding as shd
        rules = dict(shd._CTX.rules or shd.BASE_RULES)
        for k, v in list(rules.items()):
            if isinstance(v, tuple) and "pod" in v:
                rest = tuple(a for a in v if a != "pod")
                rules[k] = rest[0] if len(rest) == 1 else (rest or None)
            elif v == "pod":
                rules[k] = None
        with shd.use_sharding(shd._CTX.mesh, rules):
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # cross the slow inter-pod links compressed
        g = jax.tree.map(lambda t: compressed_allgather_mean(t, "pod"), g)
        l = jax.lax.pmean(l, "pod")
        aux = jax.tree.map(lambda t: jax.lax.pmean(t, "pod"), aux)
        return l, aux, g

    def wrapped(params, batch):
        # params replicated over pod (P()); batch dim-0 manual over pod —
        # its data-axis sharding stays auto.
        batch_specs = jax.tree.map(lambda x: P("pod"), batch)
        f = shard_map(local_grads, mesh=mesh,
                      in_specs=(jax.tree.map(lambda _: P(), params),
                                batch_specs),
                      out_specs=(P(), P(), jax.tree.map(lambda _: P(), params)),
                      axis_names={"pod"}, check_vma=False)
        return f(params, batch)

    return wrapped
