"""Tensor-parallel serve: the executed continuous engine under shard_map
on 4 fake CPU devices must be token-for-token identical to the
single-device engine — mixed-length prompts, staggered budgets, a
mid-batch EOS retirement — with a fused mixed prefill⊕decode bundle
inside each shard's program and ZERO new autotuner searches on replan
(the schedule-cache signature carries the mesh tag, so the sharded plan
caches independently of the single-device plan).  A 2-layer stacked
config exercises the lax.scan-over-layers form inside the same manual
region.  The shard-major weight permutations and the per-leaf
PartitionSpec rules are unit-tested in-process (no mesh needed)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np

from repro.distributed import sharding as shd

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# in-process: shard-major permutations + spec rules
# ---------------------------------------------------------------------------
def test_qkv_permutation_is_shard_major():
    H, Hkv, D, n = 8, 4, 4, 4
    perm = shd.tp_qkv_permutation(H, Hkv, D, n)
    assert sorted(perm) == list(range((H + 2 * Hkv) * D))   # bijection
    w = np.arange((H + 2 * Hkv) * D)
    slabs = np.take(w, perm).reshape(n, -1)
    Hl, Hkvl = H // n, Hkv // n
    for s in range(n):
        q, k, v = np.split(slabs[s], [Hl * D, (Hl + Hkvl) * D])
        # shard s's slab is [q_s | k_s | v_s] in the original numbering
        assert list(q) == list(range(s * Hl * D, (s + 1) * Hl * D))
        assert list(k) == list(range(H * D + s * Hkvl * D,
                                     H * D + (s + 1) * Hkvl * D))
        assert list(v) == list(range((H + Hkv) * D + s * Hkvl * D,
                                     (H + Hkv) * D + (s + 1) * Hkvl * D))


def test_gated_ffn_permutation_is_per_shard_gate_up():
    F, n = 12, 3
    perm = shd.tp_gated_ffn_permutation(F, n)
    assert sorted(perm) == list(range(2 * F))
    slabs = np.take(np.arange(2 * F), perm).reshape(n, -1)
    Fl = F // n
    for s in range(n):
        gate, up = np.split(slabs[s], 2)
        assert list(gate) == list(range(s * Fl, (s + 1) * Fl))
        assert list(up) == list(range(F + s * Fl, F + (s + 1) * Fl))


def test_tp_pspec_rules():
    from jax.sharding import PartitionSpec as P
    assert shd.tp_param_pspec("w_qkv", 2, "model") == P(None, "model")
    assert shd.tp_param_pspec("w_qkv", 3, "model") == P(None, None, "model")
    assert shd.tp_param_pspec("w_o", 2, "model") == P("model", None)
    assert shd.tp_param_pspec("w_out", 3, "model") == P(None, "model", None)
    assert shd.tp_param_pspec("scale", 1, "model") == P()
    assert shd.tp_cache_pspec("k", 4, "model") == P(None, None, "model",
                                                    None)
    assert shd.tp_cache_pspec("v", 5, "model") == P(None, None, None,
                                                    "model", None)
    assert shd.tp_cache_pspec("pos", 1, "model") == P()


# ---------------------------------------------------------------------------
# subprocess: 4 fake devices, sharded vs single-device differential
# ---------------------------------------------------------------------------
CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, {src!r})
    import dataclasses, tempfile
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.core import autotuner
    from repro.core.schedule_cache import ScheduleCache
    from repro.models import lm
    from repro.serve.engine import PrefillBudget, Request, ServeEngine

    cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                              dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    mesh = Mesh(np.array(jax.devices())[:4], ("model",))
    budget = PrefillBudget(chunk_rows=8)

    def requests(eos=None):
        rng = np.random.default_rng(11)
        lens, budgets = (6, 11, 7, 9, 8), (4, 6, 5, 2, 3)
        return [Request(rid=i, prompt=rng.integers(
                            1, cfg.vocab_size, L).astype(np.int32),
                        max_new_tokens=m, eos_token=eos)
                for i, (L, m) in enumerate(zip(lens, budgets))]

    def engine(**kw):
        return ServeEngine(cfg, params, batch=2, max_len=48,
                           scheduling="continuous", plan_fusion=True,
                           prefill_budget=budget, **kw)

    # EOS probe: pick a token the longest-budget request emits mid-stream
    probe = engine().run(requests())
    eos = probe[1].out_tokens[1]

    single = engine()
    a = single.run(requests(eos=eos))

    cache = ScheduleCache(tempfile.mktemp(suffix=".json"))
    tp = engine(mesh=mesh, schedule_cache=cache)
    assert tp.tp_shards == 4 and tp.executed
    b = tp.run(requests(eos=eos))

    # token-for-token parity, including the mid-batch EOS retirement
    for x, y in zip(a, b):
        assert x.out_tokens == y.out_tokens, (x.rid, x.out_tokens,
                                              y.out_tokens)
    assert any(r == "eos" for _s, _r, r in tp.stats.retirements)

    # each shard's program fuses a mixed prefill+decode bundle: SPMD traces
    # one program per shard, so the fused-chunk table IS the per-shard view
    n_top = max(n for n in tp.cb_program_info if n > 0)
    assert tp._cb_fused_chunks[n_top], "no mixed bundle in shard program"
    assert tp.cb_program_info[n_top]["fused_launches"] >= 1
    assert tp.stats.fused_mixed_steps >= 1

    # replan with the warm cache: a second sharded engine re-plans every
    # program without ONE new autotuner search
    n0 = autotuner.SEARCH_COUNT
    tp2 = engine(mesh=mesh, schedule_cache=cache)
    c = tp2.run(requests(eos=eos))
    assert autotuner.SEARCH_COUNT == n0, "sharded replan re-searched"
    assert [r.out_tokens for r in c] == [r.out_tokens for r in b]

    # stacked 2-layer config: scan-over-layers inside the manual region
    cfg2 = dataclasses.replace(cfg, num_layers=2,
                               block_pattern=("attn", "attn"))
    params2 = lm.init(cfg2, jax.random.PRNGKey(1))
    s2 = ServeEngine(cfg2, params2, batch=2, max_len=48,
                     scheduling="continuous", plan_fusion=True,
                     prefill_budget=budget)
    t2 = ServeEngine(cfg2, params2, batch=2, max_len=48,
                     scheduling="continuous", plan_fusion=True,
                     prefill_budget=budget, mesh=mesh)
    rng = np.random.default_rng(5)
    mk = lambda: [Request(rid=i, prompt=rng.integers(
                      1, cfg2.vocab_size, L).astype(np.int32),
                  max_new_tokens=m)
                  for i, (L, m) in enumerate(zip((6, 9, 7), (3, 4, 2)))]
    rng = np.random.default_rng(5); ra = s2.run(mk())
    rng = np.random.default_rng(5); rb = t2.run(mk())
    assert [r.out_tokens for r in ra] == [r.out_tokens for r in rb]

    print("SHARDED SERVE OK")
""")


def test_sharded_serve_token_parity():
    out = subprocess.run([sys.executable, "-c", CODE.format(src=SRC)],
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED SERVE OK" in out.stdout, out.stderr[-3000:]


def test_mesh_inspect_cli_reports_shard_topology():
    """``repro.tools mesh-inspect`` forces its own fake devices, plans one
    shard's program with the executed serve path's options, and reports
    which bundle members are shard-local vs replicated."""
    import json
    import os
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)               # the tool must self-provision
    out = subprocess.run(
        [sys.executable, "-m", "repro.tools", "mesh-inspect",
         "--mesh-shape", "2", "--json"],
        capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    rep = json.loads(out.stdout)
    assert rep["mesh"]["shape"] == {"model": 2}
    assert rep["tp_shards"] == 2 and rep["mesh_tag"] == "model:2"
    by_name = {o["op"]: o for o in rep["ops"]}
    norm = by_name["decode_norm1"]
    assert not norm["sharded"]
    assert norm["per_shard_shapes"] == norm["single_device_shapes"]
    qkv = by_name["qkv_proj"]
    assert qkv["sharded"]
    # the QKV weight's fused output axis halves per shard
    assert qkv["per_shard_shapes"][1][-1] * 2 == \
        qkv["single_device_shapes"][1][-1]
    members = [m for b in rep["bundles"] for m in b["members"]]
    assert any(m["sharded"] for m in members)
    assert any(not m["sharded"] for m in members)
