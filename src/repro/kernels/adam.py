"""Horizontally-fused AdamW Pallas kernel.

The optimizer step is N independent, tiny, memory-bound per-tensor updates —
exactly the paper's footnote-1 scenario (launch overhead) *plus* its main
scenario (pure memory-bound work that should overlap compute).  All tensors
are flattened into one (rows, 128) buffer and updated by a single kernel:
one launch, one long DMA stream.  The fusible OpSpec form pairs with
backward-pass matmuls in the planner (DESIGN.md §4.5).

Scalars (lr, bias corrections) ride in a tiny fp32 operand with a constant
index map (fetched once).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.op_spec import OpSpec, Operand

LANES = 128


def _adam_kernel(sc_ref, p_ref, g_ref, m_ref, v_ref,
                 po_ref, mo_ref, vo_ref, *, b1, b2, eps, wd):
    lr = sc_ref[0, 0]
    bc1 = sc_ref[0, 1]
    bc2 = sc_ref[0, 2]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1 - b1) * g
    v = b2 * v_ref[...] + (1 - b2) * g * g
    step = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * p
    po_ref[...] = (p - lr * step).astype(po_ref.dtype)
    mo_ref[...] = m
    vo_ref[...] = v


def adamw_flat(p, g, m, v, scalars, *, b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
               bm: int = 1024, interpret: bool = False):
    """p,g: (R, 128) param dtype; m,v: (R, 128) fp32; scalars: (1, 128) fp32
    holding [lr, bc1, bc2, ...].  Returns (new_p, new_m, new_v)."""
    R, C = p.shape
    assert C == LANES
    bm = min(bm, R)
    assert R % bm == 0
    kern = functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps, wd=wd)
    blk = lambda s: (s, 0)
    return pl.pallas_call(
        kern,
        grid=(R // bm,),
        in_specs=[pl.BlockSpec((1, LANES), lambda s: (0, 0)),
                  pl.BlockSpec((bm, C), blk), pl.BlockSpec((bm, C), blk),
                  pl.BlockSpec((bm, C), blk), pl.BlockSpec((bm, C), blk)],
        out_specs=[pl.BlockSpec((bm, C), blk), pl.BlockSpec((bm, C), blk),
                   pl.BlockSpec((bm, C), blk)],
        out_shape=[jax.ShapeDtypeStruct((R, C), p.dtype),
                   jax.ShapeDtypeStruct((R, C), jnp.float32),
                   jax.ShapeDtypeStruct((R, C), jnp.float32)],
        interpret=interpret,
    )(scalars, p, g, m, v)


def adamw_op(R: int, dtype=jnp.bfloat16, bm: int = 1024,
             b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
             name: str | None = None) -> OpSpec:
    """Fusible form of the flat update (grid over row blocks)."""
    assert R % bm == 0
    blk = lambda s: (s, 0)
    const = lambda s: (0, 0)

    def body(step, sc_ref, p_ref, g_ref, m_ref, v_ref, po, mo, vo):
        _adam_kernel(sc_ref, p_ref, g_ref, m_ref, v_ref, po, mo, vo,
                     b1=b1, b2=b2, eps=eps, wd=wd)

    itemsize = jnp.dtype(dtype).itemsize
    C = LANES
    return OpSpec(
        name=name or f"adamw_{R}x{C}", grid=R // bm, body=body,
        inputs=(Operand((1, C), jnp.float32, (1, C), const),
                Operand((R, C), dtype, (bm, C), blk),
                Operand((R, C), dtype, (bm, C), blk),
                Operand((R, C), jnp.float32, (bm, C), blk),
                Operand((R, C), jnp.float32, (bm, C), blk)),
        outputs=(Operand((R, C), dtype, (bm, C), blk),
                 Operand((R, C), jnp.float32, (bm, C), blk),
                 Operand((R, C), jnp.float32, (bm, C), blk)),
        flops=12.0 * R * C,
        hbm_bytes=R * C * (2 * itemsize + 3 * 4 + itemsize + 2 * 4),
        tag="framework:adamw",
        in_names=("scalars", "p", "g", "m", "v"),
        out_names=("p", "m", "v"))


# ---------------------------------------------------------------------------
# N-way multi-tensor path: one OpSpec per tensor, one fused Pallas launch
# ---------------------------------------------------------------------------
def _flatten_leaf(x, row_multiple: int = 1):
    """One leaf -> zero-padded (R, 128) buffer; R a multiple of row_multiple."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    R = math.ceil(n / LANES)
    R = math.ceil(R / row_multiple) * row_multiple
    pad = R * LANES - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(R, LANES), n


def _unflatten_leaf(flat2d, n, like):
    return flat2d.reshape(-1)[:n].reshape(like.shape).astype(like.dtype)


def multi_tensor_adamw(params, grads, m, v, scalars, *, b1=0.9, b2=0.95,
                       eps=1e-8, wd=0.1, bm: int = 1024,
                       interpret: bool = False):
    """All per-tensor updates as ONE N-way horizontally-fused launch.

    Unlike ``adamw_flat`` (which concatenates every tensor into a single
    buffer — one op, one grid), this keeps each tensor its own OpSpec and
    lets core/hfuse interleave the N update streams in a single kernel:
    the multi-tensor-apply shape that lets the planner later splice other
    ops (e.g. a dW matmul) into the same bundle.  Returns trees
    (new_params, new_m, new_v).
    """
    from repro.core import hfuse
    from repro.core.cost_model import Schedule

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(m)
    leaves_v = treedef.flatten_up_to(v)

    ops, operands, ns = [], [], []
    for i, (lp, lg, lm, lv) in enumerate(
            zip(leaves_p, leaves_g, leaves_m, leaves_v)):
        # pad each leaf's rows to a multiple of its block size so big
        # tensors keep a bm-row block (one whole-tensor block would blow
        # the VMEM budget); tiny tensors get a single block of their size
        n = math.prod(lp.shape) if lp.shape else 1
        bm_i = min(bm, math.ceil(n / LANES))
        p2, n = _flatten_leaf(lp, row_multiple=bm_i)
        g2, _ = _flatten_leaf(lg.astype(lp.dtype), row_multiple=bm_i)
        m2, _ = _flatten_leaf(lm.astype(jnp.float32), row_multiple=bm_i)
        v2, _ = _flatten_leaf(lv.astype(jnp.float32), row_multiple=bm_i)
        R = p2.shape[0]
        ops.append(adamw_op(R=R, dtype=lp.dtype, bm=bm_i,
                            b1=b1, b2=b2, eps=eps, wd=wd,
                            name=f"adamw_t{i}_{R}x{LANES}"))
        operands += [scalars, p2, g2, m2, v2]
        ns.append(n)

    fused = hfuse.generate(ops, Schedule((1,) * len(ops)),
                           interpret=interpret)
    outs = fused(*operands)
    new_p = [_unflatten_leaf(outs[3 * i], ns[i], leaves_p[i])
             for i in range(len(ops))]
    new_m = [_unflatten_leaf(outs[3 * i + 1], ns[i], leaves_m[i])
             for i in range(len(ops))]
    new_v = [_unflatten_leaf(outs[3 * i + 2], ns[i], leaves_v[i])
             for i in range(len(ops))]
    return (jax.tree.unflatten(treedef, new_p),
            jax.tree.unflatten(treedef, new_m),
            jax.tree.unflatten(treedef, new_v))


# ---------------------------------------------------------------------------
# pytree plumbing for the optimizer integration
# ---------------------------------------------------------------------------
def flatten_for_adam(tree):
    """Concatenate all leaves into one (R, 128) buffer (zero-padded)."""
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(leaves[0].dtype)
                            for l in leaves])
    n = flat.shape[0]
    R = math.ceil(n / LANES)
    pad = R * LANES - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(R, LANES), n


def unflatten_from_adam(flat2d, n, tree):
    flat = flat2d.reshape(-1)[:n]
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for l in leaves:
        k = math.prod(l.shape) if l.shape else 1
        out.append(flat[off:off + k].reshape(l.shape).astype(l.dtype))
        off += k
    return jax.tree.unflatten(treedef, out)
