"""Model/config system.

One ``ModelConfig`` describes an architecture completely enough to build it:
block pattern (which layer kind at which depth), attention flavour
(GQA / MQA / MLA / local-window), FFN flavour (dense / MoE), recurrent cores
(RG-LRU, mLSTM, sLSTM), modality frontend stubs, and the exact published dims.

Every assigned architecture lives in ``repro/configs/<id>.py`` and registers
itself here.  ``reduced()`` derives a CPU-runnable smoke config of the same
family (same block-kind diversity, tiny dims).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------
ATTN = "attn"          # global self-attention (GQA/MQA/MHA)
LOCAL_ATTN = "local"   # sliding-window self-attention
MLA = "mla"            # DeepSeek-V2 multi-head latent attention
RGLRU = "rglru"        # RecurrentGemma RG-LRU recurrent block
MLSTM = "mlstm"        # xLSTM matrix-memory LSTM block
SLSTM = "slstm"        # xLSTM scalar-memory LSTM block

SEQ_MIX_KINDS = (ATTN, LOCAL_ATTN, MLA, RGLRU, MLSTM, SLSTM)
# Kinds with O(1)-per-token decode state (no KV cache growth): allow 500k ctx.
SUBQUADRATIC_KINDS = (RGLRU, MLSTM, SLSTM, LOCAL_ATTN)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    top_k: int
    d_ff_expert: int            # per-expert hidden dim
    num_shared_experts: int = 0
    d_ff_shared: int = 0        # hidden dim of the shared expert(s), total
    router_noise: float = 0.0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                         # dense-FFN hidden dim (0 => block has its own proj)
    vocab_size: int

    # block pattern; if None, [ATTN] * num_layers
    block_pattern: tuple[str, ...] | None = None

    head_dim: int = 0                 # 0 => d_model // num_heads
    activation: str = "silu"          # silu (SwiGLU) | gelu (GeGLU) | gelu_mlp | relu2_mlp
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0        # fraction of head_dim that is rotated
    local_window: int = 2048          # for LOCAL_ATTN blocks
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    moe: Optional[MoEConfig] = None
    moe_layer_overrides: dict = field(default_factory=dict)  # layer idx -> "dense"
    dense_d_ff_first: int = 0         # DeepSeek: dense FFN dim for non-MoE first layer(s)
    mla: Optional[MLAConfig] = None

    # recurrent cores
    lru_width: int = 0                # RG-LRU width (0 => d_model)
    conv1d_width: int = 4             # temporal conv in recurrent block
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # modality frontend stubs
    frontend: str = "none"            # none | vision_stub | audio_stub
    num_image_tokens: int = 256       # vision stub: #patch embeddings prepended
    num_codebooks: int = 1            # audio: parallel EnCodec codebooks

    dtype: str = "bfloat16"
    source: str = ""                  # provenance note [arXiv/hf; tier]

    # ---------------- derived ----------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.block_pattern is not None:
            assert len(self.block_pattern) == self.num_layers, self.name
            return self.block_pattern
        return tuple([ATTN] * self.num_layers)

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def supports_long_context(self) -> bool:
        """True iff every sequence-mixing block is sub-quadratic (O(1)/O(w) state)."""
        return all(k in SUBQUADRATIC_KINDS for k in self.pattern)

    def moe_layer(self, idx: int) -> bool:
        if self.moe is None:
            return False
        return self.moe_layer_overrides.get(idx, "moe") == "moe"

    # ------------- parameter counting (for 6ND model flops) -------------
    def param_count(self) -> int:
        from repro.models import lm  # local import to avoid cycles
        return lm.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import lm
        return lm.count_params(self, active_only=True)

    # ------------- smoke-size derivation -------------
    def reduced(self) -> "ModelConfig":
        """A tiny config of the same family: keeps one run of every distinct
        block kind so smoke tests exercise every code path."""
        pat = self.pattern
        seen: list[str] = []
        for k in pat:
            if k not in seen:
                seen.append(k)
        # keep ordering representative: at most 3 blocks
        new_pat = tuple(seen[:3]) if seen else (ATTN,)
        n_heads = min(self.num_heads, 4)
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        moe = None
        overrides = {}
        if self.moe is not None:
            moe = MoEConfig(num_experts=4, top_k=min(2, self.moe.top_k),
                            d_ff_expert=64,
                            num_shared_experts=min(1, self.moe.num_shared_experts),
                            d_ff_shared=64 if self.moe.num_shared_experts else 0)
            overrides = {0: "dense"} if 0 in self.moe_layer_overrides else {}
        mla = None
        if self.mla is not None:
            mla = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=len(new_pat),
            block_pattern=new_pat,
            d_model=64,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            dense_d_ff_first=128 if self.dense_d_ff_first else 0,
            vocab_size=512,
            moe=moe,
            moe_layer_overrides=overrides,
            mla=mla,
            lru_width=64 if self.lru_width else 0,
            local_window=32,
            num_image_tokens=8,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned; identical set for every LM arch)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason-if-not). long_500k needs sub-quadratic seq mixing."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention arch: 500k dense KV decode is out of scope "
                       "per assignment (needs sub-quadratic attention); see DESIGN.md §6")
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import (  # noqa: F401
        recurrentgemma_2b, xlstm_1_3b, internvl2_1b, stablelm_3b,
        starcoder2_7b, minitron_8b, granite_3_2b, deepseek_v2_236b,
        phi35_moe, musicgen_medium,
    )
    _LOADED = True
