"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --scale smoke --steps 50 --ckpt-dir /tmp/ckpt [--resume] \
      [--hfused-optimizer] [--compression int8_pod] [--zero]

``--scale smoke`` runs the reduced config on local devices (CPU-runnable
end-to-end driver); ``--scale full`` expects the production mesh.
Fault tolerance: async checkpoints every --ckpt-every steps, auto-resume,
straggler watchdog with data-pipeline skip-ahead, bounded restart loop.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, TokenPipeline
from repro.distributed import sharding as shd
from repro.models import lm
from repro.train import checkpoint, optimizer as opt_mod
from repro.train.fault_tolerance import StepWatchdog, run_with_restarts
from repro.train.train_loop import TrainConfig, make_train_step
from repro.train.optimizer import AdamWConfig


def build(cfg, tcfg: TrainConfig, mesh=None, update_program=None):
    params = lm.init(cfg, jax.random.PRNGKey(0))
    opt_state = opt_mod.init(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg, mesh,
                                      update_program=update_program),
                      donate_argnums=(0, 1))
    return params, opt_state, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--hfused-optimizer", action="store_true")
    ap.add_argument("--plan-fusion", action="store_true",
                    help="plan optimizer/backward fusion bundles AND execute "
                         "the optimizer step through the plan->program "
                         "executor (core/executor)")
    ap.add_argument("--dry-steps", type=int, default=None,
                    help="run only N steps with checkpointing disabled "
                         "(CI executor smoke)")
    ap.add_argument("--measure", choices=["auto", "interpret", "tpu", "gpu"],
                    default=None,
                    help="pick planned schedules by measurement "
                         "(core/timing.make_measure backend)")
    ap.add_argument("--compression", choices=["int8_pod"], default=None)
    ap.add_argument("--zero", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--max-failures", type=int, default=3)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)
    if args.measure and not args.plan_fusion:
        ap.error("--measure only applies to --plan-fusion schedule selection")
    if args.dry_steps is not None:
        args.steps = args.dry_steps
        args.ckpt_dir = ""

    cfg = get_config(args.arch)
    if args.scale == "smoke":
        cfg = cfg.reduced()
    ocfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(1, args.steps // 10),
                      hfused=args.hfused_optimizer)
    tcfg = TrainConfig(optimizer=ocfg, grad_accum=args.grad_accum,
                       compression=args.compression, zero=args.zero,
                       remat=args.scale == "full")

    update_program = None
    if args.plan_fusion:
        from repro.core.schedule_cache import default_cache
        from repro.core.timing import make_measure
        from repro.train.train_loop import (build_update_program,
                                            plan_update_fusion)
        measure = make_measure(args.measure) if args.measure else None
        abstract_params = jax.eval_shape(
            lambda: lm.init(cfg, jax.random.PRNGKey(0)))
        fplan = plan_update_fusion(
            abstract_params, tokens=args.batch * args.seq, measure=measure,
            cache=default_cache())
        print("[plan-fusion] optimizer/backward bundles (planning view):")
        for row in fplan.summary():
            print(f"  {row}")
        # the executed hot path: every leaf's update, lowered plan->program
        update_program = build_update_program(
            abstract_params, ocfg, measure=measure, cache=default_cache())
        print("[plan-fusion] executed update program "
              f"({update_program.program.n_fused} fused launches):")
        for row in update_program.describe():
            print(f"  {row}")

    mesh = None
    if args.scale == "full":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()

    data = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch,
        num_codebooks=cfg.num_codebooks if cfg.frontend == "audio_stub" else 0,
        num_image_tokens=cfg.num_image_tokens
        if cfg.frontend == "vision_stub" else 0,
        d_model=cfg.d_model))

    ckpt = (checkpoint.AsyncCheckpointer(args.ckpt_dir)
            if args.ckpt_dir else None)
    watchdog = StepWatchdog()

    def make_state():
        params, opt_state, step_fn = build(cfg, tcfg, mesh, update_program)
        start = 0
        if ckpt and args.resume:
            got = checkpoint.restore_latest(
                args.ckpt_dir, {"params": params,
                                "m": opt_state.m, "v": opt_state.v})
            if got:
                start, tree, meta = got
                params = tree["params"]
                opt_state = opt_mod.OptState(
                    m=tree["m"], v=tree["v"],
                    count=jnp.asarray(start, jnp.int32))
                data.restore({"step": start, "shard": 0})
                print(f"[resume] from step {start}")
        return dict(params=params, opt=opt_state, step_fn=step_fn, start=start)

    def loop(state, _failures):
        params, opt_state, step_fn = state["params"], state["opt"], state["step_fn"]
        losses = []
        for step in range(state["start"], args.steps):
            t0 = time.time()
            batch = jax.tree.map(jnp.asarray, data.batch_at(step))
            params, opt_state, metrics = step_fn(params, opt_state, batch,
                                                 jnp.asarray(step))
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if watchdog.observe(step, dt):
                data.skip_ahead(0)   # single-host: log only
                print(f"[straggler] step {step} took {dt:.2f}s")
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt * 1e3:.0f}ms",
                      flush=True)
            if ckpt and step and step % args.ckpt_every == 0:
                ckpt.save_async(step, {"params": params, "m": opt_state.m,
                                       "v": opt_state.v},
                                {"loss": loss})
        if ckpt:
            ckpt.save_async(args.steps, {"params": params, "m": opt_state.m,
                                         "v": opt_state.v}, {})
            ckpt.wait()
        if losses:
            print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
        else:
            print(f"nothing to do: resumed at step {state['start']} "
                  f">= --steps {args.steps}")
        return losses

    return run_with_restarts(make_state, loop, max_failures=args.max_failures,
                             on_restart=lambda n: print(f"[restart #{n}]"))


if __name__ == "__main__":
    main()
