"""Measured-mode autotuning report — predicted vs measured per bundle.

  PYTHONPATH=src python -m benchmarks.measured [--backend interpret|device]

For every registered paper_suite triple, run the two-stage measured search
(``autotuner.search(measure=...)``) and emit
``BENCH_measured_<backend>_<git-sha>.json``: per-bundle best schedule, cost-model
prediction, measurement, their delta, and the search-economics columns
(measure() invocations vs the exhaustive lattice size — the paper's Main()
loop would have profiled the whole lattice).  CI runs this in interpret
mode on every push (`benchmarks/run.py --smoke --measure interpret`) and
uploads the JSON as a build artifact, so the perf trajectory accumulates.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def git_sha(short: int = 8) -> str:
    """Short git SHA for report filenames — multi-host runs (and successive
    commits) stop clobbering each other's BENCH artifacts."""
    sha = os.environ.get("GITHUB_SHA", "")
    if not sha:
        try:
            sha = subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
                cwd=Path(__file__).resolve().parent, timeout=10,
            ).stdout.strip()
        except (OSError, subprocess.SubprocessError):
            sha = ""
    return sha[:short] or "nogit"


def run(backend: str = "interpret", *, small: bool = True,
        out_path: str | None = None) -> dict:
    from repro.core import autotuner
    from repro.core.timing import make_measure
    from repro.kernels import paper_suite as ps

    measure = make_measure(backend, execute=(backend == "interpret" and small))
    calls = [0]
    base_measure = measure

    def counted(fused, *ops):
        calls[0] += 1
        return base_measure(fused, *ops)
    counted.backend = getattr(base_measure, "backend", backend)

    rows = []
    for names in ps.paper_triples():
        ops, _, _ = ps.make_bundle(names, small=small)
        calls[0] = 0
        res = autotuner.search(tuple(ops), measure=counted)
        # the acceptance invariant, enforced where CI can see it: measured
        # search must beat exhaustive profiling on every registered triple
        assert res.n_measured == calls[0] < res.lattice_size, \
            (names, res.n_measured, calls[0], res.lattice_size)
        best = res.best
        rows.append({
            "bundle": "+".join(names),
            "sched": best.sched.label(),
            "vmem_cap": best.vmem_cap,
            "predicted_us": best.est.t_hfused * 1e6,
            "measured_us": (None if best.measured_s is None
                            else best.measured_s * 1e6),
            "cm_vs_measured_delta_pct": best.delta_pct(),
            "predicted_speedup_pct": best.est.speedup_pct(),
            "n_measured": res.n_measured,
            "lattice_size": res.lattice_size,
        })
        print(f"# measured {rows[-1]['bundle']}: sched {rows[-1]['sched']} "
              f"delta {rows[-1]['cm_vs_measured_delta_pct']:.1f}% "
              f"({res.n_measured}/{res.lattice_size} profiled)")

    report = {"backend": getattr(measure, "backend", backend),
              "small": small, "git_sha": git_sha(), "rows": rows}
    out = Path(out_path
               or f"BENCH_measured_{report['backend']}_{report['git_sha']}.json")
    out.write_text(json.dumps(report, indent=1))
    print(f"# wrote {out}")
    return report


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="interpret")
    ap.add_argument("--full", action="store_true",
                    help="full-size ops (device backends only — interpret "
                         "execution at full size is intractable)")
    args = ap.parse_args()
    run(args.backend, small=not args.full)
