"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory, strictly sequential).

TPU adaptation: the mLSTM is evaluated in *chunkwise* form for train/prefill —
within a chunk the gated outer-product recurrence is expressed as masked
matmuls (MXU-friendly), across chunks a lax.scan carries the (C, n, m)
stabilized state.  This matches the sequential recurrence exactly
(tests/test_models_xlstm.py checks chunked == sequential).  Decode is the
O(1) recurrent step — which is why this arch runs the 500k-context shape.

The sLSTM's pointwise recurrent chain is the paper's "memory-intensive
kernel" archetype: long dependent chains of cheap VPU ops — prime fodder for
horizontal fusion with compute-bound neighbours (DESIGN.md §6).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec
from repro.runtime_flags import maybe_scan
from repro.models.rglru import _causal_conv

NEG = -1e30


# ===========================================================================
# mLSTM
# ===========================================================================
def mlstm_spec(cfg) -> dict:
    d = cfg.d_model
    f = int(cfg.mlstm_proj_factor * d)
    qk = f // 2
    H = cfg.num_heads
    return {
        "w_up": ParamSpec((d, 2 * f), ("embed", "ffn")),         # [x_m | z-gate]
        "conv_w": ParamSpec((cfg.conv1d_width, f), (None, "ffn")),
        "conv_b": ParamSpec((f,), ("ffn",), "zeros"),
        "w_q": ParamSpec((f, qk), ("ffn", "qkv")),
        "w_k": ParamSpec((f, qk), ("ffn", "qkv")),
        "w_v": ParamSpec((f, f), ("ffn", "qkv")),
        "w_gates": ParamSpec((f, 2 * H), ("ffn", None)),          # [ĩ | f̃] per head
        "gate_b": ParamSpec((2 * H,), (None,), "zeros", dtype="float32"),
        "out_norm": ParamSpec((f,), ("ffn",), "zeros", dtype="float32"),
        "w_down": ParamSpec((f, d), ("ffn", "embed"), "out_proj"),
    }


def mlstm_dims(cfg):
    d = cfg.d_model
    f = int(cfg.mlstm_proj_factor * d)
    H = cfg.num_heads
    return f, f // 2, H, (f // 2) // H, f // H      # f, qk, H, dk, dv


def _headnorm(scale, x):
    """Per-head RMS norm over the last dim, then learned scale over flat dim.
    x: (B, S, H, dv) -> (B, S, H*dv)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6)
    y = y.reshape(y.shape[:-2] + (-1,)) * (1.0 + scale)
    return y


def mlstm_seq(q, k, v, i_pre, f_pre, state):
    """Sequential reference recurrence (oracle; also usable for decode S=1).

    q,k: (B,S,H,dk); v: (B,S,H,dv); gates (B,S,H).  fp32 state
    (C (B,H,dk,dv), n (B,H,dk), m (B,H)).
    """
    B, S, H, dk = q.shape
    scale = 1.0 / math.sqrt(dk)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs                       # (B,H,dk) ... (B,H)
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        fw = jnp.exp(logf + m - m_new)[..., None]
        iw = jnp.exp(it - m_new)[..., None]
        C = C * fw[..., None] + iw[..., None] * (kt[..., :, None] * vt[..., None, :])
        n = n * fw + iw * kt
        qs = qt * scale
        num = jnp.einsum("bhd,bhdv->bhv", qs, C)
        qn = jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n))
        h = num / jnp.maximum(qn, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h

    xs = (q.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          i_pre.transpose(1, 0, 2).astype(jnp.float32),
          f_pre.transpose(1, 0, 2).astype(jnp.float32))
    state, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3), state            # (B,S,H,dv)


def mlstm_chunked(q, k, v, i_pre, f_pre, state, chunk: int = 256):
    """Chunkwise-parallel stabilized mLSTM — same math as mlstm_seq.

    Within-chunk: masked-matmul form (MXU).  Across chunks: scan on
    stabilized (C, n, m).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    scale = 1.0 / math.sqrt(dk)

    def cview(x, dlast):
        # (B,S,H,d) -> (nc, B, H, L, d) for scan
        return (x.reshape(B, nc, L, H, dlast).transpose(1, 0, 3, 2, 4)
                .astype(jnp.float32))

    qs = cview(q, dk) * scale
    ks = cview(k, dk)
    vs = cview(v, dv)
    gi = i_pre.reshape(B, nc, L, H).transpose(1, 0, 3, 2).astype(jnp.float32)
    gf = jax.nn.log_sigmoid(
        f_pre.reshape(B, nc, L, H).transpose(1, 0, 3, 2).astype(jnp.float32))

    causal = jnp.tril(jnp.ones((L, L), bool))

    def step(carry, xs):
        C0, n0, m0 = carry                            # fp32
        qb, kb, vb, ib, fb = xs                       # (B,H,L,·) / (B,H,L)
        b = jnp.cumsum(fb, axis=-1)
        u = ib - b
        m_i = jnp.maximum(m0[..., None] + b, b + jax.lax.cummax(u, axis=2))
        # D_ij = exp(b_i - m_i + u_j) for j <= i
        D = jnp.exp(b[..., :, None] - m_i[..., :, None] + u[..., None, :])
        D = jnp.where(causal[None, None], D, 0.0)
        s = jnp.einsum("bhid,bhjd->bhij", qb, kb) * D
        inter_w = jnp.exp(b + m0[..., None] - m_i)    # (B,H,L)
        num = (jnp.einsum("bhij,bhjv->bhiv", s, vb)
               + jnp.einsum("bhid,bhdv->bhiv", qb, C0) * inter_w[..., None])
        qn = s.sum(-1) + jnp.einsum("bhid,bhd->bhi", qb, n0) * inter_w
        h = num / jnp.maximum(jnp.abs(qn), jnp.exp(-m_i))[..., None]
        # chunk-end state
        bL = b[..., -1:]
        mL = m_i[..., -1]
        w = jnp.exp(bL - mL[..., None] + u)           # (B,H,L)
        decay = jnp.exp(bL[..., 0] + m0 - mL)
        C1 = C0 * decay[..., None, None] + jnp.einsum("bhj,bhjd,bhjv->bhdv",
                                                      w, kb, vb)
        n1 = n0 * decay[..., None] + jnp.einsum("bhj,bhjd->bhd", w, kb)
        return (C1, n1, mL), h

    state, hs = maybe_scan(step, state, (qs, ks, vs, gi, gf))
    # (nc,B,H,L,dv) -> (B,S,H,dv)
    hs = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dv)
    return hs, state


def mlstm_fresh_state(B, H, dk, dv):
    return (jnp.zeros((B, H, dk, dv), jnp.float32),
            jnp.zeros((B, H, dk), jnp.float32),
            jnp.full((B, H), NEG, jnp.float32))


def _mlstm_qkvg(cfg, p, x, conv0=None):
    f, qk, H, dk, dv = mlstm_dims(cfg)
    B, S, _ = x.shape
    xm, z = jnp.split(x @ p["w_up"], 2, axis=-1)
    if conv0 is not None:
        cat = jnp.concatenate([conv0.astype(xm.dtype), xm], axis=1)
        c = _causal_conv(cat, p["conv_w"], p["conv_b"])[:, conv0.shape[1]:]
    else:
        c = _causal_conv(xm, p["conv_w"], p["conv_b"])
    c = jax.nn.silu(c)
    q = (c @ p["w_q"]).reshape(B, S, H, dk)
    k = (c @ p["w_k"]).reshape(B, S, H, dk)
    v = (xm @ p["w_v"]).reshape(B, S, H, dv)
    gates = (xm @ p["w_gates"]).astype(jnp.float32) + p["gate_b"]
    i_pre, f_pre = gates[..., :H], gates[..., H:]
    K = cfg.conv1d_width
    conv_tail = xm[:, -(K - 1):, :]
    return q, k, v, i_pre, f_pre, z, conv_tail


def mlstm_apply_train(cfg, p, x, state=None, conv0=None):
    """x: (B,S,d) -> (y, (state, conv_tail))."""
    f, qk, H, dk, dv = mlstm_dims(cfg)
    B, S, _ = x.shape
    q, k, v, i_pre, f_pre, z, conv_tail = _mlstm_qkvg(cfg, p, x, conv0)
    if state is None:
        state = mlstm_fresh_state(B, H, dk, dv)
    # pad S to a chunk multiple if needed (smoke sizes)
    chunk = 256 if S % 256 == 0 else S
    h, state = mlstm_chunked(q, k, v, i_pre, f_pre, state, chunk=chunk)
    y = _headnorm(p["out_norm"], h).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["w_down"], (state, conv_tail)


def mlstm_apply_decode(cfg, p, x_t, state, conv_buf):
    """x_t: (B,1,d); conv_buf: (B,K-1,f)."""
    f, qk, H, dk, dv = mlstm_dims(cfg)
    B = x_t.shape[0]
    xm, z = jnp.split(x_t @ p["w_up"], 2, axis=-1)
    window = jnp.concatenate([conv_buf.astype(xm.dtype), xm], axis=1)
    c = jax.nn.silu(jnp.einsum("bkf,kf->bf", window, p["conv_w"]) + p["conv_b"])
    q = (c @ p["w_q"]).reshape(B, 1, H, dk)
    k = (c @ p["w_k"]).reshape(B, 1, H, dk)
    v = (xm[:, 0] @ p["w_v"]).reshape(B, 1, H, dv)
    gates = (xm[:, 0] @ p["w_gates"]).astype(jnp.float32) + p["gate_b"]
    h, state = mlstm_seq(q, k, v, gates[:, None, :H], gates[:, None, H:], state)
    y = _headnorm(p["out_norm"], h).astype(x_t.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["w_down"], state, window[:, 1:, :].astype(conv_buf.dtype)


# ===========================================================================
# sLSTM
# ===========================================================================
def slstm_spec(cfg) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    fs = int(cfg.slstm_proj_factor * d)
    return {
        "conv_w": ParamSpec((cfg.conv1d_width, d), (None, "embed")),
        "conv_b": ParamSpec((d,), ("embed",), "zeros"),
        # §Perf iteration 2: the sLSTM recurrence stays UNsharded on the
        # model axis — its per-step block-diag contraction would psum (B,d)
        # every timestep (S=4096 psums/layer under TP); its flops are <5% of
        # any cell, so replicated compute beats per-step collectives.
        "w_zifo": ParamSpec((d, 4 * d), ("embed", None)),
        "r_zifo": ParamSpec((4, H, dh, dh), (None, None, None, None)),
        "b_zifo": ParamSpec((4 * d,), (None,), "zeros", dtype="float32"),
        "out_norm": ParamSpec((d,), ("embed",), "zeros", dtype="float32"),
        "w_up": ParamSpec((d, 2 * fs), ("embed", "ffn")),
        "w_down": ParamSpec((fs, d), ("ffn", "embed"), "out_proj"),
    }


def _slstm_cell(p, wx_t, state):
    """One recurrence step.  wx_t: (B, 4d) fp32 precomputed W@x + b;
    state = (c, n, m, h) each (B, d) fp32."""
    c, n, m, h = state
    H, dh, _ = p["r_zifo"].shape[1:]
    d = c.shape[-1]
    hh = h.reshape(-1, H, dh)
    r = jnp.einsum("bhi,ghij->gbhj", hh, p["r_zifo"].astype(jnp.float32))
    r = r.reshape(4, -1, d)
    z_pre, i_pre, f_pre, o_pre = [wx_t[..., j * d:(j + 1) * d] + r[j]
                                  for j in range(4)]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    fw = jnp.exp(logf + m - m_new)
    iw = jnp.exp(i_pre - m_new)
    c_new = fw * c + iw * z
    n_new = fw * n + iw
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_fresh_state(B, d):
    z = jnp.zeros((B, d), jnp.float32)
    return (z, z, jnp.full((B, d), NEG, jnp.float32), z)


def slstm_apply_train(cfg, p, x, state=None, conv0=None):
    """x: (B,S,d) — sequential lax.scan over time."""
    B, S, d = x.shape
    if conv0 is not None:
        cat = jnp.concatenate([conv0.astype(x.dtype), x], axis=1)
        c = _causal_conv(cat, p["conv_w"], p["conv_b"])[:, conv0.shape[1]:]
    else:
        c = _causal_conv(x, p["conv_w"], p["conv_b"])
    c = jax.nn.silu(c)
    # i,f gates see the conv features; z,o see the raw input (official layout)
    wz = x @ p["w_zifo"][:, : d]
    wi = c @ p["w_zifo"][:, d: 2 * d]
    wf = c @ p["w_zifo"][:, 2 * d: 3 * d]
    wo = x @ p["w_zifo"][:, 3 * d:]
    wx = jnp.concatenate([wz, wi, wf, wo], axis=-1).astype(jnp.float32) \
        + p["b_zifo"]
    if state is None:
        state = slstm_fresh_state(B, d)
    state, hs = jax.lax.scan(lambda s, w: _slstm_cell(p, w, s), state,
                             wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2)                              # (B,S,d) fp32
    # per-head norm + gated FFN
    H = cfg.num_heads
    y = _headnorm(p["out_norm"], h.reshape(B, S, H, d // H)).astype(x.dtype)
    up = y @ p["w_up"]
    g, u = jnp.split(up, 2, axis=-1)
    y = (jax.nn.silu(g) * u) @ p["w_down"]
    K = cfg.conv1d_width
    conv_tail = x[:, -(K - 1):, :]
    return y, (state, conv_tail)


def slstm_apply_decode(cfg, p, x_t, state, conv_buf):
    """x_t: (B,1,d)."""
    B, _, d = x_t.shape
    window = jnp.concatenate([conv_buf.astype(x_t.dtype), x_t], axis=1)
    c = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"])
    x0 = x_t[:, 0]
    wx = jnp.concatenate(
        [x0 @ p["w_zifo"][:, :d], c @ p["w_zifo"][:, d:2 * d],
         c @ p["w_zifo"][:, 2 * d:3 * d], x0 @ p["w_zifo"][:, 3 * d:]],
        axis=-1).astype(jnp.float32) + p["b_zifo"]
    state, h = _slstm_cell(p, wx, state)
    H = cfg.num_heads
    y = _headnorm(p["out_norm"], h.reshape(B, 1, H, d // H)).astype(x_t.dtype)
    g, u = jnp.split(y @ p["w_up"], 2, axis=-1)
    y = (jax.nn.silu(g) * u) @ p["w_down"]
    return y, state, window[:, 1:, :].astype(conv_buf.dtype)
