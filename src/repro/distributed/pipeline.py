"""GPipe-style pipeline parallelism over the 'pod' axis.

The multi-pod mesh's `pod` axis defaults to pure DP (one gradient reduction
per step over the slow inter-pod links).  For models whose layers do not fit
a single pod, this module instead maps *pipeline stages* onto pods:
microbatch activations flow stage→stage via `collective_permute` (one small
(B_micro, S, d) hop per tick over the inter-pod link instead of full-gradient
traffic), with the classic GPipe fill/drain bubble of (S−1)/(M+S−1).

Implementation: `jax.shard_map` manual over 'pod' only (auto over
(data, model): each stage's interior keeps its normal SPMD sharding).
Stage parameters are stacked on a leading axis sharded P('pod') — each pod
holds exactly its stage's weights.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import shard_map


def gpipe(stage_fn: Callable, n_stages: int, axis: str = "pod"):
    """Build a pipelined forward: (stage_params_local, xs) -> ys.

    stage_fn(params, x) -> y, same signature for every stage (homogeneous
    stages — layer runs are grouped upstream).  Used inside a shard_map that
    is manual over `axis`; xs: (M, ...) microbatches (replicated over
    `axis`); returns (M, ...) outputs valid on the LAST stage (other stages
    return the in-flight garbage — callers read stage n_stages-1 or
    ppermute the result back).
    """
    def pipelined(params_local, xs):
        M = xs.shape[0]
        stage = jax.lax.axis_index(axis)
        n_ticks = M + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, outs = carry                      # buf: activation entering
            mb_idx = jnp.clip(t, 0, M - 1)
            first_in = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0,
                                                    keepdims=False)
            inp = jnp.where(stage == 0, first_in, buf)
            out = stage_fn(params_local, inp)
            # collect on the last stage once the pipe is full
            out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            collect = (stage == n_stages - 1) & (t >= n_stages - 1)
            outs = jax.lax.cond(
                collect,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, out_idx, 0),
                lambda o: o, outs)
            nxt = jax.lax.ppermute(out, axis, perm)
            return (nxt, outs), None

        buf0 = jnp.zeros_like(stage_fn(params_local,
                                       jax.tree.map(lambda a: a[0], xs)))
        outs0 = jnp.zeros((M,) + buf0.shape, buf0.dtype)
        (buf, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                      jnp.arange(n_ticks))
        return outs

    return pipelined


def pipeline_over_pods(stage_fn: Callable, mesh: Mesh, n_stages: int):
    """shard_map wrapper: stage params stacked on dim0 (P('pod')), inputs
    microbatched on dim0 (replicated over pod), outputs broadcast from the
    last stage back to all pods."""
    inner = gpipe(stage_fn, n_stages)

    def run(stage_params_stacked, xs):
        def body(params_stk, xs_local):
            params_local = jax.tree.map(lambda a: a[0], params_stk)
            ys = inner(params_local, xs_local)
            # broadcast final outputs from the last stage to every pod
            # (masked psum: ppermute cannot fan out one source to many)
            stage = jax.lax.axis_index("pod")
            ys = jnp.where(stage == n_stages - 1, ys, jnp.zeros_like(ys))
            return jax.lax.psum(ys, "pod")

        f = shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P("pod"), stage_params_stacked),
                      P()),
            out_specs=P(),
            axis_names={"pod"}, check_vma=False)
        return f(stage_params_stacked, xs)

    return run
