"""Batched serving engine: continuous batching with per-slot cache positions.

Semantics (``scheduling="continuous"``, the default): the engine keeps a
per-slot cache-position vector ``(B,)`` plus per-slot active masks, so every
slot advances, finishes (EOS / token budget / cache-full) and is refilled
independently at every iteration.  A waiting request's prompt is prefilled
*alongside* the decode step that runs in the same iteration — the planner
therefore sees a mixed prefill⊕decode op graph on (nearly) every step, not
only at wave boundaries.  The slot lifecycle, the ``(B,)`` position
contract and the fallback rules are documented in docs/serving.md.

The legacy wavefront scheduler (``scheduling="wavefront"``) is retained:
requests are grouped by prompt length into lock-step waves and the batch
only refills when a whole wave finishes.  It is the differential oracle the
continuous engine is tested against (tests/test_serve_continuous.py).

Fusion execution (``plan_fusion=True``): the decode step is *planned* by
``plan_decode_fusion`` and *executed* through the plan->program executor
(core/executor) — the norm -> decode-attention -> FFN-projection chain runs
as Pallas kernels routed by a binding registry over the live slot state
(hidden activations, the KV-cache blocks, the layer weights), with the
model glue (QKV projection, per-slot RoPE, per-slot cache scatter,
residuals, gating, head) living in the binding setters.  Decode attention
reads each slot's valid prefix from a vectorized ``(B, 1)`` int32 operand,
so one compiled kernel serves every mix of slot positions.

Chunked prefill (``PrefillBudget``): on the executed continuous path a
waiting prompt is admitted in chunks of ``chunk_rows`` tokens — the slot
enters a *prefilling* phase, each iteration scatters one chunk's k/v into
the slot's cache rows and runs the blockwise flash-prefill kernel
(kernels/prefill_attention) for that chunk *inside the decode step's fused
launch*.  Up to ``max_coresident_chunks`` chunks from different slots ride
one launch: N compute-bound prefill-attention ops ⊕ the memory-bound
vectorized decode attention, the paper's heterogeneous pairing as ONE
Pallas call.  Prompts of any length (up to the cache) are chipped away
across iterations; the first token samples from the final chunk's logits.
Configs outside the supported shape (multi-run stacks, MoE, non-RMSNorm)
fall back to the hand-wired ``lm.decode_step`` with a notice
(``executable_decode_supported`` returns the reason; see docs/serving.md
§Fallback).

``examples/dual_stream_decode.py`` shows the horizontal-fusion dual-stream
variant of the decode step.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.models import lm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_token: Optional[int] = None
    arrival: int = 0                   # engine step at which the request is
    #                                    visible to the slot manager
    #                                    (continuous scheduling only)
    out_tokens: list = field(default_factory=list)
    done: bool = False


@dataclass(frozen=True)
class PrefillBudget:
    """One iteration's prefill allowance — the single knob that replaced
    the ``prefill_rows`` / ``prefill_chunk`` / ``pad_prefill_rows`` trio.

    ``chunk_rows``: tokens of one prompt consumed per iteration (one
    prefill-attention chunk).  ``max_coresident_chunks``: how many chunks
    from *different* slots may ride one fused launch.  ``pad_to``: lane
    tile the legacy wavefront prefill-FFN operand rows pad to.
    ``policy``: which prefilling slots chunk first when more are ready
    than ``max_coresident_chunks`` allows — ``"fifo"`` (lowest slot index,
    the legacy order), ``"srpf"`` (shortest-remaining-prefill-first:
    prompts closest to completion chunk first, cutting mean admission
    latency on mixed short/long traces; ties break by slot index), or
    ``"eload"`` (expert-load-aware: srpf ordering, but when the running
    per-expert hit skew — ``ServeStats.expert_skew`` — reaches
    ``skew_threshold`` the step sheds one coresident chunk, narrowing the
    launch while the hot experts' weight streaming dominates the fused
    bundle's memory phase; MoE executed path only — without expert stats
    the skew stays 0 and eload degrades to srpf)."""
    chunk_rows: int = 2048
    max_coresident_chunks: int = 2
    pad_to: int = 128
    policy: str = "fifo"
    skew_threshold: float = 1.5

    def __post_init__(self):
        for f_ in ("chunk_rows", "max_coresident_chunks", "pad_to"):
            if getattr(self, f_) < 1:
                raise ValueError(f"PrefillBudget.{f_} must be >= 1")
        if self.policy not in ("fifo", "srpf", "eload"):
            raise ValueError(
                f"PrefillBudget.policy {self.policy!r} "
                "(fifo, srpf or eload)")
        if self.skew_threshold < 1.0:
            raise ValueError("PrefillBudget.skew_threshold must be >= 1.0 "
                             "(1.0 means perfectly balanced experts)")

    def pad_rows(self, rows: int) -> int:
        """Rows of a prefill FFN operand: raw up to one tile, the next
        ``pad_to`` multiple beyond (zero-padded)."""
        return rows if rows <= self.pad_to else \
            -(-rows // self.pad_to) * self.pad_to

    def effective_chunk(self, cache_len: int, multiple: int = 1) -> int:
        """Chunk rows actually used against a ``cache_len`` cache: the
        largest value <= min(chunk_rows, cache_len) dividing cache_len, so
        chunk offsets are always multiples of the chunk and a full-chunk
        scatter never crosses the cache end.  ``multiple`` further
        constrains the chunk to a multiple of it (the paged path passes the
        KV block size so every chunk is a whole number of pages); when even
        ``multiple`` itself exceeds ``chunk_rows`` it is returned as the
        minimum viable chunk.

        Direct divisor enumeration over ``sqrt(cache_len)`` pairs — the
        answer is by definition a divisor, so counting down from
        ``chunk_rows`` one integer at a time (the old loop) did O(cache_len)
        work for what is an O(sqrt) question.
        """
        if cache_len % multiple:
            raise ValueError(f"cache_len {cache_len} is not a multiple of "
                             f"the required alignment {multiple}")
        n = cache_len // multiple
        cap = max(min(self.chunk_rows, cache_len) // multiple, 1)
        best, i = 1, 1
        while i * i <= n:
            if n % i == 0:
                for d in (i, n // i):
                    if best < d <= cap:
                        best = d
            i += 1
        return best * multiple


@dataclass
class ServeStats:
    """Slot-manager trajectory of one continuous-batching ``run()``."""
    batch: int
    steps: int = 0                # engine iterations (incl. idle/prefill-only)
    decode_steps: int = 0         # iterations that decoded >= 1 active slot
    mixed_steps: int = 0          # decode iterations that also carried a
    #                               prefill chunk (the steady mixed graph)
    fused_mixed_steps: int = 0    # mixed iterations whose program fused a
    #                               prefill chunk with decode-side work
    prefill_only_steps: int = 0   # admissions with no active slot to decode
    slot_steps: int = 0           # sum of active slots over decode iterations
    tokens: int = 0
    prefill_chunks: int = 0       # chunk launches (chunked admission)
    fused_prefill_chunks: int = 0  # chunks whose program fused them with a
    #                                decode-side member (attention or the
    #                                FFN chain riding the other bundle)
    admissions: list = field(default_factory=list)   # (step, rid, slot)
    retirements: list = field(default_factory=list)  # (step, rid, reason)
    admission_latencies: list = field(default_factory=list)  # steps from
    #                                  arrival to first token, per admission
    # paged-KV trajectory (serve/kv_pool.py; zero on the contiguous path)
    prompt_tokens: int = 0        # prompt tokens across admitted requests
    prefix_hits: int = 0          # admissions that matched a cached prefix
    prefix_tokens_reused: int = 0  # prompt tokens whose prefill was skipped
    blocks_in_use: int = 0        # peak arena blocks mapped or cached
    evictions: int = 0            # prefix-cache blocks evicted under pressure
    # MoE trajectory (executed path only; empty/zero for dense configs)
    expert_hits: list = field(default_factory=list)  # per-expert routed
    #                               decode-token count, layer-summed
    load_shed_steps: int = 0      # steps where eload shed a coresident chunk

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots decoding per decode iteration."""
        return self.slot_steps / max(self.batch * self.decode_steps, 1)

    @property
    def mixed_fraction(self) -> float:
        """Fraction of decode iterations that carried a prefill partner."""
        return self.mixed_steps / max(self.decode_steps, 1)

    @property
    def fused_prefill_fraction(self) -> float:
        """Fraction of prefill chunks that rode a fused launch with
        decode-side work (vs launching as planner singles)."""
        return self.fused_prefill_chunks / max(self.prefill_chunks, 1)

    @property
    def mean_admission_latency(self) -> float:
        """Mean engine steps from request arrival to its first token."""
        lat = self.admission_latencies
        return sum(lat) / len(lat) if lat else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prompt tokens whose prefill the prefix cache
        skipped entirely (paged KV only)."""
        return self.prefix_tokens_reused / max(self.prompt_tokens, 1)

    def add_expert_hits(self, counts) -> None:
        """Accumulate one step's per-expert decode-token counts (an (E,)
        vector off the device, summed over layers)."""
        counts = [int(c) for c in counts]
        if not self.expert_hits:
            self.expert_hits = [0] * len(counts)
        for i, c in enumerate(counts):
            self.expert_hits[i] += c

    @property
    def expert_skew(self) -> float:
        """Hottest expert's load relative to a perfectly balanced one:
        max(hits) * E / sum(hits).  1.0 = balanced, E = every routed
        token hit one expert; 0.0 until any hits land (dense configs,
        or before the first decode step)."""
        total = sum(self.expert_hits)
        if not total:
            return 0.0
        return max(self.expert_hits) * len(self.expert_hits) / total

    def describe(self) -> dict:
        return {
            "steps": self.steps, "decode_steps": self.decode_steps,
            "mixed_steps": self.mixed_steps,
            "fused_mixed_steps": self.fused_mixed_steps,
            "prefill_only_steps": self.prefill_only_steps,
            "tokens": self.tokens,
            "prefill_chunks": self.prefill_chunks,
            "fused_prefill_chunks": self.fused_prefill_chunks,
            "occupancy": round(self.occupancy, 3),
            "mixed_fraction": round(self.mixed_fraction, 3),
            "fused_prefill_fraction": round(self.fused_prefill_fraction, 3),
            "mean_admission_latency": round(self.mean_admission_latency, 3),
            "prefix_hits": self.prefix_hits,
            "prefix_hit_rate": round(self.prefix_hit_rate, 3),
            "blocks_in_use": self.blocks_in_use,
            "evictions": self.evictions,
            "expert_hits": list(self.expert_hits),
            "expert_skew": round(self.expert_skew, 3),
            "load_shed_steps": self.load_shed_steps,
        }


def executable_decode_supported(cfg: ModelConfig) -> Optional[str]:
    """None when the planned decode program can replace ``lm.decode_step``
    for this config; otherwise the reason for the hand-wired fallback."""
    runs = lm.layer_runs(cfg)
    if cfg.frontend != "none":
        return f"frontend {cfg.frontend!r} (token frontend only)"
    if len(runs) != 1 or runs[0].kind != ATTN:
        return "needs a single global-attention layer run"
    if cfg.norm != "rmsnorm":
        return f"norm {cfg.norm!r} (rmsnorm only)"
    if not cfg.is_moe and cfg.d_ff <= 0:
        return "no FFN"
    if cfg.activation not in ("silu", "gelu", "gelu_mlp", "relu2_mlp"):
        return f"activation {cfg.activation!r}"
    return None


def _ffn_in_width(cfg: ModelConfig) -> int:
    """Width of the decode step's FFN in-projection — the real ``w_in``
    (gated activations fuse gate+up into one (d, 2f) matmul)."""
    if cfg.moe is not None:
        return cfg.moe.num_experts
    if cfg.d_ff <= 0:
        return cfg.d_model
    return 2 * cfg.d_ff if cfg.activation in ("silu", "gelu") else cfg.d_ff


def pad_prefill_rows(rows: int) -> int:
    """Deprecated: use ``PrefillBudget.pad_rows`` (the padding tile is a
    budget policy now, not a module constant)."""
    warnings.warn("pad_prefill_rows is deprecated — use "
                  "PrefillBudget.pad_rows", DeprecationWarning, stacklevel=2)
    return PrefillBudget().pad_rows(rows)


def _mlp_from_h(cfg: ModelConfig, h, w_out):
    """layers.mlp, minus the in-projection the executor already ran."""
    act = cfg.activation
    if act in ("silu", "gelu"):
        gate, up = jnp.split(h, 2, axis=-1)
        g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
        h = g * up
    elif act == "gelu_mlp":
        h = jax.nn.gelu(h)
    elif act == "relu2_mlp":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(act)
    return h @ w_out


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch: int = 8,
                 max_len: int = 512, rng_seed: int = 0,
                 plan_fusion: bool = False, measure=None,
                 schedule_cache=None, scheduling: str = "continuous",
                 prefill_budget: Optional[PrefillBudget] = None,
                 reject_overlong: bool = False,
                 stitch_epilogues: bool = True,
                 paged_kv: bool = False, kv_block_size: int = 16,
                 kv_slot_blocks: Optional[int] = None,
                 kv_blocks: Optional[int] = None,
                 mesh=None, shard_axis: str = "model"):
        if scheduling not in ("continuous", "wavefront"):
            raise ValueError(f"scheduling {scheduling!r} "
                             "(continuous or wavefront)")
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.scheduling = scheduling
        # tensor-parallel serve: with a mesh whose ``shard_axis`` has
        # extent n > 1, the executed continuous step runs under
        # compat.shard_map — each shard owns num_heads/n query heads,
        # num_kv_heads/n KV-cache heads and d_ff/n FFN columns, plans its
        # own shard-local fusion, and psums the two row-sharded output
        # projections.  The slot manager, the per-slot (B,) position
        # contract and every sampled token stay shard-replicated.
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.tp_shards = 1
        if mesh is not None and dict(mesh.shape).get(shard_axis, 1) > 1:
            n_tp = int(dict(mesh.shape)[shard_axis])
            if scheduling != "continuous" or not plan_fusion:
                raise ValueError(
                    "tensor-parallel serve requires scheduling='continuous' "
                    "and plan_fusion=True (only the executed continuous "
                    "step runs under shard_map)")
            reason = executable_decode_supported(cfg)
            if reason is not None:
                raise ValueError("tensor-parallel serve: config not "
                                 f"executor-supported ({reason})")
            if cfg.is_moe:
                raise ValueError(
                    "tensor-parallel serve: MoE expert weights are "
                    "expert-major, not head/column-sharded — serve MoE "
                    "single-device (expert parallelism is a ROADMAP item)")
            for what, dim in (("num_heads", cfg.num_heads),
                              ("num_kv_heads", cfg.num_kv_heads),
                              ("d_ff", cfg.d_ff)):
                if dim % n_tp:
                    raise ValueError(
                        f"tensor-parallel serve: {what}={dim} is not "
                        f"divisible by mesh axis {shard_axis!r} extent "
                        f"{n_tp}")
            self.tp_shards = n_tp
        self._mesh_tag = (f"{shard_axis}:{self.tp_shards}"
                          if self.tp_shards > 1 else "")
        self.paged_kv = paged_kv
        self.kv_pool = None
        if paged_kv:
            # paged KV rides the executed chunked path: the arena gather
            # lives in the paged kernels, the table bookkeeping in
            # serve/kv_pool.py — neither exists on the fallback paths
            if scheduling != "continuous" or not plan_fusion:
                raise ValueError("paged_kv requires scheduling='continuous' "
                                 "and plan_fusion=True (the paged kernels "
                                 "run only on the executed chunked path)")
            reason = executable_decode_supported(cfg)
            if reason is None and lm.layer_runs(cfg)[0].count > 1:
                reason = ("the paged arena is single-layer — stacked runs "
                          "serve from the contiguous cache")
            if reason is None and cfg.is_moe:
                reason = ("MoE decode serves from the contiguous cache "
                          "(the paged+MoE combination is untested)")
            if reason is not None:
                raise ValueError(f"paged_kv: config not executor-supported "
                                 f"({reason}) — the vmapped fallback has no "
                                 "paged cache")
            if kv_block_size < 1 or 128 % kv_block_size:
                raise ValueError(f"kv_block_size {kv_block_size} must divide "
                                 "128 (cache lengths and kv chunks are "
                                 "128-aligned)")
            self.kv_block_size = kv_block_size
            if kv_slot_blocks is None:
                kv_slot_blocks = self._aligned_len() // kv_block_size
            if (kv_slot_blocks * kv_block_size) % 128:
                raise ValueError("kv_slot_blocks * kv_block_size = "
                                 f"{kv_slot_blocks * kv_block_size} must be "
                                 "a multiple of 128")
            self.kv_slot_blocks = kv_slot_blocks
            # default arena: every slot can hold its full logical capacity
            # (parity-by-construction with the contiguous cache); tighter
            # arenas degrade through LRU eviction, not rejection
            if kv_blocks is None:
                kv_blocks = batch * kv_slot_blocks + batch
            self.kv_blocks = kv_blocks
            from repro.serve.kv_pool import KVPool
            # the pool persists across run() calls: the prefix trie keeps
            # retired prompts' blocks cached, so a later run sharing a
            # prefix skips those chunks too
            self.kv_pool = KVPool(num_blocks=kv_blocks,
                                  block_size=kv_block_size, slots=batch,
                                  max_blocks_per_slot=kv_slot_blocks)
        # stitch_epilogues=False keeps the decode graph's producer→consumer
        # pairs as separate planner ops — the honest unstitched baseline the
        # differential tests and benchmarks compare against
        self.stitch_epilogues = stitch_epilogues
        self.prefill_budget = prefill_budget or PrefillBudget()
        self.reject_overlong = reject_overlong
        self.rng = jax.random.PRNGKey(rng_seed)
        self._measure = measure
        self._schedule_cache = schedule_cache
        self._decode = jax.jit(
            lambda p, c, t: lm.decode_step(cfg, p, c, t))
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(cfg, p, b, max_len=self.cache_len))

        self.executed = False
        self._mixed_steps: dict[int, object] = {}   # prompt len -> jitted step
        #                                             (wavefront co-prefill)
        self._cb_steps: dict[int, object] = {}      # n chunks -> jitted step
        #                                             (continuous, executed)
        self._cb_fused_chunks: dict[int, frozenset] = {}  # n chunks -> chunk
        #                                             indices the program
        #                                             fused with decode attn
        self.cb_program_info: dict[int, dict] = {}  # n chunks -> launch
        #                                             table (the supported
        #                                             reporting accessor)
        self._cb_decode = None                      # generic vmapped fallback
        self._refill_write = None
        self.stats = ServeStats(batch=batch)
        # the executed continuous step decodes with _step_params: the plain
        # params single-device, the shard-major-permuted copy under TP (see
        # _tp_permuted_params — shard_map's even last-axis split then hands
        # each shard a self-consistent [q_s|k_s|v_s] / [gate_s|up_s] slab)
        self._step_params = params
        if self.tp_shards > 1:
            self._step_params = self._tp_permuted_params()
        self.fusion_plan = None
        if plan_fusion:
            reason = executable_decode_supported(cfg)
            if reason is None and scheduling == "wavefront" \
                    and lm.layer_runs(cfg)[0].count > 1:
                reason = ("stacked layer runs execute on the continuous "
                          "path only (wavefront keeps the hand-wired step)")
            if reason is None and scheduling == "wavefront" and cfg.is_moe:
                reason = ("MoE decode executes on the continuous path only "
                          "(the wavefront co-prefill glue is dense-FFN "
                          "shaped)")
            if reason is None:
                # the executed decode program indexes the cache by the
                # planned (128-aligned) length; ``cache_len`` exposes it —
                # ``max_len`` stays exactly what the caller configured
                if scheduling == "wavefront":
                    # the continuous path builds its own per-P steps
                    # (_cb_step) lazily; only wavefront decodes through
                    # this program
                    self._decode = jax.jit(
                        self._make_decode_step(prefill_len=0))
                self.executed = True
            else:
                print(f"[plan-fusion] decode step stays hand-wired: {reason}")
            self.fusion_plan = self.plan_decode_fusion(
                measure=measure, cache=schedule_cache)

    # ------------------------------------------------------------------
    def _aligned_len(self) -> int:
        return max(128, -(-self.max_len // 128) * 128)

    @property
    def cache_len(self) -> int:
        """Rows of cache a slot can actually hold — the admission and
        retirement limit.  ``max_len`` is immutable (exactly what the
        caller configured); the executed paths size their cache to the
        128-aligned length, and the paged path to the per-slot block-table
        span, so capacity can EXCEED ``max_len`` (a paged engine with
        ``kv_slot_blocks`` raised serves prompts the contiguous contract
        would reject)."""
        if getattr(self, "paged_kv", False):
            return self.kv_slot_blocks * self.kv_block_size
        if getattr(self, "executed", False):
            return self._aligned_len()
        return self.max_len

    def decode_graph(self, *, budget: Optional[PrefillBudget] = None,
                     prefill_chunks: int = 0, ffn_rows: int = 0,
                     dynamic_length: bool = True,
                     prefill_rows: Optional[int] = None):
        """The serving step as a planner graph, with stable operand
        signatures (core/binding.py): decode-slot RMSNorm -> decode
        attention (per-slot valid prefixes in a (B, 1) int32 operand) ->
        post-attention RMSNorm -> the router/FFN in-projection.

        ``prefill_chunks=N`` adds N independent blockwise flash-prefill
        attention ops (kernels/prefill_attention) — one prompt chunk of one
        prefilling slot each, ``budget.effective_chunk`` rows against the
        slot's whole cache.  Compute-bound at scale, they are the paper's
        heterogeneous partners for the memory-bound decode attention.

        ``ffn_rows>0`` adds the legacy wavefront co-prefill partner: the
        riding prompt's FFN in-projection matmul.  (``prefill_rows`` is the
        deprecated alias for it.)  With neither, the graph is a pure decode
        step: a dependency chain the planner correctly leaves unfused.

        For executor-supported configs the graph carries the decode step's
        epilogue chains (core/stitch.py): the pre-attention RMSNorm declares
        the QKV projection matmul as its epilogue consumer, and the FFN
        in-projection declares the activation — the planner contracts each
        pair into one stitched member whose intermediate never touches HBM.
        ``stitch_epilogues=False`` on the engine keeps the same six ops as
        separate nodes (the unstitched baseline).
        """
        from repro.core import planner
        from repro.kernels import elementwise
        from repro.kernels.decode_attention import decode_attention_op
        from repro.kernels.matmul import matmul_1d_op
        from repro.kernels.prefill_attention import prefill_attention_op
        from repro.kernels.rmsnorm import rmsnorm_op

        if prefill_rows is not None:
            warnings.warn("decode_graph(prefill_rows=) is deprecated — use "
                          "ffn_rows (wavefront FFN partner) or "
                          "prefill_chunks + PrefillBudget (chunked prefill)",
                          DeprecationWarning, stacklevel=2)
            ffn_rows = prefill_rows
        budget = budget or self.prefill_budget
        cfg = self.cfg
        d, H, Hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
        D = cfg.resolved_head_dim
        # tensor-parallel: the graph the planner sees is ONE SHARD's —
        # local head counts and local FFN width.  d_model (activations)
        # stays replicated, so every row dimension is unchanged.
        tp = getattr(self, "tp_shards", 1)
        H, Hkv = H // tp, Hkv // tp
        ffn_in = _ffn_in_width(cfg) // tp
        ffn_out = cfg.d_ff // tp
        dt = jnp.dtype(cfg.dtype)
        paged = getattr(self, "paged_kv", False)
        # paged: S is the per-slot LOGICAL capacity spanned by the block
        # table (a 128-multiple by construction); contiguous: the
        # 128-aligned cache length
        S = self.cache_len if paged else self._aligned_len()
        bt = (self.kv_blocks, self.kv_block_size) if paged else None
        B = self.batch

        norm1 = dataclasses.replace(rmsnorm_op(R=B, d=d, dtype=dt, bm=B),
                                    name="decode_norm1")
        norm2 = dataclasses.replace(rmsnorm_op(R=B, d=d, dtype=dt, bm=B),
                                    name="decode_norm2")
        # largest 128-multiple chunk <= 1024 that divides S (S is 128-aligned,
        # so the scan bottoms out at ck=128; kv_block_size divides 128, so a
        # paged kv-chunk is always a whole number of pages)
        ck = next(c for c in range(min(1024, S), 0, -128) if S % c == 0)
        att = decode_attention_op(B=B, S=S, H=H, Hkv=Hkv, D=D, dtype=dt,
                                  ck=ck, dynamic_length=dynamic_length,
                                  block_table=bt)
        # decode-slot projection: MoE router when the model routes, else the
        # FFN in-projection — weight streaming dominates at serving batch
        # (memory-bound; the honest fig_framework finding), so the planner
        # pairs it with the prefill chunk's genuinely compute-bound matmul.
        proj = matmul_1d_op(M=B, K=d, N=ffn_in, dtype=dt, bm=B)
        proj = dataclasses.replace(
            proj, name="moe_router" if cfg.moe is not None else "ffn_proj")
        executable = executable_decode_supported(cfg) is None
        if executable and cfg.moe is not None:
            # Executed MoE decode: the router projection and the grouped
            # expert GMM (kernels/moe_gmm) are planner ops; the top-k /
            # softmax / dispatch-gather / combine-scatter glue lives in the
            # binding slots between them (build_decode_program).  The
            # router's logits stay fp32 (its own matmul op) so the softmax
            # and top-k see exactly what the vmapped fallback computes;
            # capacity is static per program (capacity(cfg, B) — the same
            # function route_from_logits resolves at trace time).
            from repro.kernels.moe_gmm import moe_gmm_op
            from repro.models import moe as moe_mod
            m = cfg.moe
            qkv = dataclasses.replace(
                matmul_1d_op(M=B, K=d, N=(H + 2 * Hkv) * D, dtype=dt, bm=B),
                name="qkv_proj")
            proj = dataclasses.replace(
                matmul_1d_op(M=B, K=d, N=m.num_experts,
                             dtype=jnp.float32, bm=B),
                name="moe_router")
            gated = cfg.activation in ("silu", "gelu")
            gmm = moe_gmm_op(
                E=m.num_experts, C=moe_mod.capacity(cfg, B), d=d,
                f=m.d_ff_expert, dtype=dt,
                act=cfg.activation if gated else "gelu", gated=gated)
            if getattr(self, "stitch_epilogues", True):
                norm1 = dataclasses.replace(norm1,
                                            epilogue=(qkv.name, "x"))
            # the expert GMM sits at the end of the decode dependency
            # chain, so its fused partners are the independent prefill
            # chunks — expert weight streaming (memory-bound) riding the
            # chunk's compute-bound attention, the paper's pairing
            graph = [planner.GraphOp(norm1),
                     planner.GraphOp(qkv, deps=frozenset({norm1.name})),
                     planner.GraphOp(att, deps=frozenset({qkv.name})),
                     planner.GraphOp(norm2, deps=frozenset({att.name})),
                     planner.GraphOp(proj, deps=frozenset({norm2.name})),
                     planner.GraphOp(gmm, deps=frozenset({proj.name}))]
        elif executable:
            # Executor-supported configs plan the QKV projection and the FFN
            # activation as graph ops (not binding glue), so each
            # producer→consumer pair can stitch into one launch.  Stitched or
            # not, the op set and numerics are identical — only the epilogue
            # declarations below differ.
            qkv = dataclasses.replace(
                matmul_1d_op(M=B, K=d, N=(H + 2 * Hkv) * D, dtype=dt, bm=B),
                name="qkv_proj")
            act_fn = {"silu": elementwise.silu_gate,
                      "gelu": elementwise.gelu_gate,
                      "gelu_mlp": elementwise.gelu_plain,
                      "relu2_mlp": elementwise.relu2}[cfg.activation]
            act = elementwise.activation_op(
                R=B, F_in=ffn_in, F_out=ffn_out, fn=act_fn,
                dtype=dt, bm=B, name="decode_act")
            if getattr(self, "stitch_epilogues", True):
                norm1 = dataclasses.replace(norm1,
                                            epilogue=(qkv.name, "x"))
                proj = dataclasses.replace(proj,
                                           epilogue=(act.name, "h"))
            # precise single-reader dataflow: norm1 feeds ONLY qkv (att
            # consumes the projected q/k/v, not the normed x), and proj
            # feeds ONLY the activation — the contraction pre-pass checks
            # exactly this
            graph = [planner.GraphOp(norm1),
                     planner.GraphOp(qkv, deps=frozenset({norm1.name})),
                     planner.GraphOp(att, deps=frozenset({qkv.name})),
                     planner.GraphOp(norm2, deps=frozenset({att.name})),
                     planner.GraphOp(proj, deps=frozenset({norm2.name})),
                     planner.GraphOp(act, deps=frozenset({proj.name}))]
        else:
            # fallback graph (MoE, stacked runs, ...): QKV/activation stay
            # binding glue; dataflow norm1 -> attention -> norm2 -> proj
            graph = [planner.GraphOp(norm1),
                     planner.GraphOp(att, deps=frozenset({norm1.name})),
                     planner.GraphOp(norm2, deps=frozenset({norm1.name,
                                                            att.name})),
                     planner.GraphOp(proj, deps=frozenset({norm2.name}))]
        if ffn_rows:
            # the wavefront co-prefill partner is a full-FFN-width matmul
            # (compute-bound at scale) — for MoE that is the *expert* FFN
            # in-projection (gate+up fused when gated), not the tiny router
            # projection the decode side plans and not the dense cfg.d_ff
            pf_n = ((2 * cfg.moe.d_ff_expert
                     if cfg.activation in ("silu", "gelu")
                     else cfg.moe.d_ff_expert)
                    if cfg.moe is not None else _ffn_in_width(cfg))
            pf = matmul_1d_op(M=ffn_rows, K=d, N=pf_n,
                              dtype=dt, bm=min(128, ffn_rows))
            pf = dataclasses.replace(pf, name="prefill_ffn")
            graph.append(planner.GraphOp(pf))
        if prefill_chunks:
            C = budget.effective_chunk(
                S, multiple=self.kv_block_size if paged else 1)
            sfx = f"_pg{self.kv_block_size}" if paged else ""
            for i in range(prefill_chunks):
                pa = prefill_attention_op(
                    C, S, H, Hkv, D, dtype=dt, ck=ck, block_table=bt,
                    name=f"prefill_attn{i}_C{C}_S{S}_H{H}kv{Hkv}{sfx}")
                graph.append(planner.GraphOp(pa))
        return graph

    def plan_decode_fusion(self, *, max_ways: Optional[int] = None,
                           budget: Optional[PrefillBudget] = None,
                           measure=None, cache=None,
                           prefill_chunk: Optional[int] = None):
        """Register the serving step's ops as a planner graph (ROADMAP) and
        plan the bundles; ``build_decode_program`` lowers the result onto
        the live slot state.  The graph carries the budget's full chunk
        complement (``max_coresident_chunks`` flash-prefill ops), so the
        plan shown at engine start is the steady mixed-iteration plan.
        With ``measure`` the schedule is profiled, and ``cache`` makes
        every later engine start skip the search entirely.
        """
        from repro.core import planner

        if prefill_chunk is not None:
            warnings.warn("plan_decode_fusion(prefill_chunk=) is deprecated "
                          "— pass budget=PrefillBudget(chunk_rows=...)",
                          DeprecationWarning, stacklevel=2)
            budget = dataclasses.replace(budget or self.prefill_budget,
                                         chunk_rows=prefill_chunk)
        budget = budget or self.prefill_budget
        n = budget.max_coresident_chunks
        if max_ways is None:
            max_ways = 2 + n                 # {att, chunk_0..chunk_{n-1}} +1
        graph = self.decode_graph(budget=budget, prefill_chunks=n)
        return planner.plan(graph, max_ways=max_ways, measure=measure,
                            cache=cache,
                            mesh_tag=getattr(self, "_mesh_tag", ""))

    # ------------------------------------------------------------------
    # Executed decode step: plan -> program -> live slot state
    # ------------------------------------------------------------------
    def build_decode_program(self, *, prefill_chunks: int = 0,
                             ffn_rows: int = 0,
                             interpret: Optional[bool] = None,
                             prefill_rows: Optional[int] = None):
        """Compile the planned decode step into an executor Program bound to
        the live slot state.  The binding setters carry the model glue: the
        norm's output slot projects QKV, applies RoPE at each slot's own
        position and scatters k/v into each slot's cache row (masked by the
        per-slot ``act`` vector, so prefilling/idle slots never see a stale
        garbage write); the attention output slot applies W_o and the
        residual; the projection output slot finishes the MLP and the
        second residual.  Each of the ``prefill_chunks`` flash-prefill ops
        reads its own slot's cache rows (``pf{i}_slot``) at its own chunk
        offset (``pf{i}_off``) — the step function scatters the chunk's k/v
        *before* the program runs.  The state's ``pos`` key is the per-slot
        position vector ``(B,)`` — the wavefront path broadcasts its scalar
        wave position into it (see ``_wave_state``).  ``prefill_rows`` is
        the deprecated alias for ``ffn_rows``.
        """
        from repro.core import executor, planner, stitch
        from repro.core.binding import BindingRegistry, Slot
        from repro.models import layers

        if prefill_rows is not None:
            warnings.warn("build_decode_program(prefill_rows=) is "
                          "deprecated — use ffn_rows (wavefront FFN "
                          "partner) or prefill_chunks (chunked prefill)",
                          DeprecationWarning, stacklevel=2)
            ffn_rows = prefill_rows
        cfg = self.cfg
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        d, H, Hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
        D = cfg.resolved_head_dim
        # tensor-parallel: the program is traced once and runs SPMD inside
        # shard_map — all head splits below are shard-local, the weight
        # state leaves arrive as shards, and the two row-sharded output
        # projections psum their partial products across ``shard_axis``
        tp = getattr(self, "tp_shards", 1)
        axis = getattr(self, "shard_axis", "model")
        H, Hkv = H // tp, Hkv // tp
        dt = jnp.dtype(cfg.dtype)
        B = self.batch

        graph = self.decode_graph(prefill_chunks=prefill_chunks,
                                  ffn_rows=ffn_rows)
        # allow_same_bound: at full scale the prefill chunk is genuinely
        # compute-bound (the paper pairing); at smoke scale everything is
        # memory-bound and the launch/ramp amortization still decides —
        # admission stays the planner's, never forced
        plan = planner.plan(graph, max_ways=max(3, 2 + prefill_chunks),
                            allow_same_bound=True,
                            measure=self._measure,
                            cache=self._schedule_cache,
                            mesh_tag=getattr(self, "_mesh_tag", ""))

        paged = getattr(self, "paged_kv", False)
        bs = self.kv_block_size if paged else 0

        def qkv_put(state, qkv):
            # the planned QKV matmul's output: split heads, RoPE at each
            # slot's own position, act-masked cache scatter (mirrors
            # layers.qkv_project's slicing exactly).  Paged: the scatter
            # routes through each slot's block-table row — writes land at
            # (table[b, pos//bs], pos % bs) in the arena.  An idle slot's
            # table row points at its private sentinel block and a
            # prefilling slot's next block is its own (admission floors
            # prefix reuse to whole chunks), so the masked no-op rewrites
            # can never land on a block another slot shares.
            qkv = qkv.astype(dt)[:, None, :]                    # (B, 1, N)
            q = qkv[..., :H * D].reshape(B, 1, H, D)
            k = qkv[..., H * D:(H + Hkv) * D].reshape(B, 1, Hkv, D)
            v = qkv[..., (H + Hkv) * D:].reshape(B, 1, Hkv, D)
            positions = state["pos"].reshape(B, 1)              # per-slot
            q = layers.rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
            k = layers.rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
            state = dict(state)
            state["q"] = q[:, 0]
            rows = jnp.arange(B)
            if paged:
                rows = state["bt"][rows, state["pos"] // bs]    # arena blocks
                cols = state["pos"] % bs
            else:
                cols = state["pos"]
            # act-masked scatter: only decoding slots land k/v — a
            # prefilling slot's row at `pos` is live chunk data this very
            # step and must not be clobbered by its stale last-token write
            act = state["act"][:, None, None]
            k_row = jnp.where(act, k[:, 0], state["k_cache"][rows, cols])
            v_row = jnp.where(act, v[:, 0], state["v_cache"][rows, cols])
            state["k_cache"] = state["k_cache"].at[rows, cols].set(k_row)
            state["v_cache"] = state["v_cache"].at[rows, cols].set(v_row)
            return state

        def att_put(state, o):
            attn_out = o.astype(dt).reshape(B, H * D) @ state["w_o"]
            if tp > 1:          # row-sharded W_o: sum the partial products
                attn_out = jax.lax.psum(attn_out, axis)
            state = dict(state)
            state["h_mid"] = state["x"] + attn_out              # residual 1
            return state

        def act_put(state, h_act):
            ff = h_act.astype(dt) @ state["w_out"]
            if tp > 1:          # row-sharded W_out: sum the partial products
                ff = jax.lax.psum(ff, axis)
            state = dict(state)
            state["x_out"] = state["h_mid"] + ff                # residual 2
            return state

        # bindings follow the CONTRACTED graph: a stitched chain is one node
        # exposing only external operands, so it binds once under its chain
        # name; if the planner left a pair unstitched (or the engine was
        # built with stitch_epilogues=False) each op binds separately with
        # the intermediate routed through a named state slot
        plan_names = {g.op.name for g in plan.graph}
        reg = BindingRegistry()
        chain1 = stitch.chain_label("decode_norm1", "qkv_proj")
        if chain1 in plan_names:
            reg.bind(chain1, x="x", scale="norm1_scale", w="w_qkv",
                     outputs={"out": Slot(put=qkv_put)})
        else:
            reg.bind("decode_norm1", x="x", scale="norm1_scale",
                     outputs={"out": "x_normed"})
            reg.bind("qkv_proj", x="x_normed", w="w_qkv",
                     outputs={"out": Slot(put=qkv_put)})
        att_name = next(g.op.name for g in graph
                        if g.op.name.startswith("decode_attn"))
        att_in = {"len": Slot(get=lambda s: (s["pos"] + 1)
                              .reshape(B, 1).astype(jnp.int32))}
        if paged:
            att_in["bt"] = "bt"               # (B, max_blocks) device table
        reg.bind(att_name, q="q", k="k_cache", v="v_cache",
                 inputs=att_in,
                 outputs={"o": Slot(put=att_put), "m": "attn_m",
                          "l": "attn_l"})
        reg.bind("decode_norm2", x="h_mid", scale="norm2_scale",
                 outputs={"out": "h2"})
        gmm_name = next((g.op.name for g in graph
                         if g.op.name.startswith("moe_gmm")), None)
        if gmm_name is not None:
            # MoE: the router matmul and the grouped expert GMM are planner
            # ops; everything between them — softmax/top-k, the sort-based
            # capacity dispatch, the combine scatter — is binding glue.
            # Both glue bodies mirror models/moe.apply() line for line
            # (same fp32 logits, same dt combine multiply, same
            # expert-major scatter-add order) so the executed path is
            # token-for-token the vmapped fallback.
            from repro.models import moe as moe_mod
            m = cfg.moe

            def router_put(state, logits):
                # logits (B, E) fp32 straight off the planned matmul
                r = moe_mod.route_from_logits(cfg, logits)
                state = dict(state)
                h_pad = jnp.concatenate(
                    [state["h2"], jnp.zeros((1, d), state["h2"].dtype)])
                state["moe_xe"] = h_pad[r.dispatch_idx]      # (E, C, d)
                state["moe_dispatch"] = r.dispatch_idx
                state["moe_combine"] = r.combine_w
                # per-expert hit counts over *decoding* slots only — the
                # act mask zeroes prefilling/idle rows and the B-index
                # padding row, so the host-side load stats see real load
                act_pad = jnp.concatenate(
                    [state["act"].astype(jnp.int32),
                     jnp.zeros((1,), jnp.int32)])
                state["expert_counts"] = act_pad[r.dispatch_idx].sum(axis=1)
                return state

            def gmm_put(state, ye):
                # combine: weight each expert row, scatter-add back to its
                # token (expert-major order, matching apply()); shared
                # experts run dense on the same normed hidden
                state = dict(state)
                ye = ye * state["moe_combine"][..., None].astype(ye.dtype)
                out = jnp.zeros((B + 1, d), ye.dtype).at[
                    state["moe_dispatch"].reshape(-1)].add(
                    ye.reshape(-1, d))[:B]
                if m.num_shared_experts:
                    h = state["h2"] @ state["shared_w_in"]
                    if cfg.activation in ("silu", "gelu"):
                        g_, u_ = jnp.split(h, 2, axis=-1)
                        h = (jax.nn.silu(g_) if cfg.activation == "silu"
                             else jax.nn.gelu(g_)) * u_
                    else:
                        h = jax.nn.gelu(h)
                    out = out + h @ state["shared_w_out"]
                state["x_out"] = state["h_mid"] + out.astype(dt)  # residual 2
                return state

            # the router reads h2 widened to fp32 — exactly the fallback's
            # x2d.astype(float32) @ router_w
            reg.bind("moe_router",
                     inputs={"x": Slot(get=lambda s:
                                       s["h2"].astype(jnp.float32)),
                             "w": "w_router"},
                     outputs={"out": Slot(put=router_put)})
            reg.bind(gmm_name, xe="moe_xe", w_in="w_in", w_out="w_out",
                     outputs={"ye": Slot(put=gmm_put)})
        else:
            proj_name = "moe_router" if cfg.moe is not None else "ffn_proj"
            chain2 = stitch.chain_label(proj_name, "decode_act")
            if chain2 in plan_names:
                reg.bind(chain2, x="h2", w="w_in",
                         outputs={"out": Slot(put=act_put)})
            else:
                reg.bind(proj_name, x="h2", w="w_in",
                         outputs={"out": "h_ffn"})
                reg.bind("decode_act", h="h_ffn",
                         outputs={"out": Slot(put=act_put)})
        if ffn_rows:
            reg.bind("prefill_ffn", x="pf_h2", w="w_in", outputs={"out": "pf_ffn"})
        for g in graph:
            if not g.op.name.startswith("prefill_attn"):
                continue
            i = int(g.op.name.split("_")[1][4:])      # prefill_attn{i}_...
            # the chunk reads ITS OWN slot's cache rows — a (S, Hkv, D)
            # gather the decode scatter never touches (act masks that slot).
            # Paged: k/v are the WHOLE shared arena and the chunk's slot
            # contributes its (1, max_blocks) table row instead.
            if paged:
                pf_in = {"off": f"pf{i}_off", "q": f"pf{i}_q",
                         "k": "k_cache", "v": "v_cache",
                         "bt": Slot(get=lambda s, i=i:
                                    s["bt"][s[f"pf{i}_slot"]][None])}
            else:
                pf_in = {"off": f"pf{i}_off", "q": f"pf{i}_q",
                         "k": Slot(get=lambda s, i=i:
                                   s["k_cache"][s[f"pf{i}_slot"]]),
                         "v": Slot(get=lambda s, i=i:
                                   s["v_cache"][s[f"pf{i}_slot"]])}
            reg.bind(g.op.name, inputs=pf_in,
                     outputs={"o": f"pf{i}_o", "m": f"pf{i}_m",
                              "l": f"pf{i}_l"})
        return executor.compile_plan(plan, bindings=reg, interpret=interpret)

    def _layer_state(self, p, kv, x, pos, act):
        """State pytree for ONE layer of the executed program: ``p`` is the
        layer's block params, ``kv`` its ``{"k", "v"}`` cache leaves (the
        scan over stacked runs feeds per-layer slices of both); ``pos`` is
        the per-slot position vector (B,), ``act`` the per-slot decoding
        mask (B,) bool gating the decode k/v scatter."""
        state = {
            "x": x, "pos": pos, "act": act,
            "norm1_scale": p["norm1"]["scale"].reshape(1, -1),
            "norm2_scale": p["norm2"]["scale"].reshape(1, -1),
            "w_qkv": p["attn"]["w_qkv"], "w_o": p["attn"]["w_o"],
            "k_cache": kv["k"], "v_cache": kv["v"],
        }
        if "moe" in p:
            # expert-major leaves: the router projection plus the grouped
            # GMM's (E, d, fin)/(E, f, d) weight stacks (models/moe.spec)
            state["w_router"] = p["moe"]["router"]
            state["w_in"] = p["moe"]["w_in"]
            state["w_out"] = p["moe"]["w_out"]
            if self.cfg.moe.num_shared_experts:
                state["shared_w_in"] = p["moe"]["shared_w_in"]
                state["shared_w_out"] = p["moe"]["shared_w_out"]
        else:
            state["w_in"] = p["mlp"]["w_in"]
            state["w_out"] = p["mlp"]["w_out"]
        return state

    def _slot_state(self, params, cache, x, pos, act):
        """Single-layer form of ``_layer_state`` over the full param/cache
        trees (the wavefront path and unstacked configs)."""
        run = lm.layer_runs(self.cfg)[0]
        return self._layer_state(params[run.name], cache[run.name],
                                 x, pos, act)

    # ------------------------------------------------------------------
    # Tensor parallelism: shard-major weight layout + shard_map specs
    # ------------------------------------------------------------------
    def _tp_permuted_params(self):
        """Params copy whose fused column-sharded weights are permuted to
        shard-major order (distributed/sharding.py): w_qkv's [q|k|v] column
        blocks become per-shard [q_s|k_s|v_s], a gated w_in's [gate|up]
        becomes per-shard [gate_s|up_s] — shard_map's even last-axis split
        then hands every shard a slab the unmodified head-split and
        gate-split glue consumes directly.  Row-sharded weights (w_o,
        w_out) and everything replicated pass through untouched."""
        from repro.distributed import sharding as shd
        cfg = self.cfg
        run = lm.layer_runs(cfg)[0]
        p = dict(self.params)
        blk = dict(p[run.name])
        attn = dict(blk["attn"])
        attn["w_qkv"] = shd.tp_permute_qkv(
            attn["w_qkv"], cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, self.tp_shards)
        blk["attn"] = attn
        if cfg.activation in ("silu", "gelu"):
            mlp = dict(blk["mlp"])
            mlp["w_in"] = shd.tp_permute_gated_ffn(
                mlp["w_in"], cfg.d_ff, self.tp_shards)
            blk["mlp"] = mlp
        p[run.name] = blk
        return p

    def _tp_specs(self, n_chunks: int):
        """(in_specs, out_specs) for shard_map around the continuous step:
        weight and KV-cache leaves shard by name (sharding.tp_param_pspec /
        tp_cache_pspec), everything the slot manager owns — tokens, masks,
        positions, chunk metadata, block tables — replicates."""
        from jax.sharding import PartitionSpec as P
        from jax.tree_util import tree_map_with_path
        from repro.distributed import sharding as shd
        axis = self.shard_axis

        p_specs = tree_map_with_path(
            lambda path, leaf: shd.tp_param_pspec(path[-1].key,
                                                  jnp.ndim(leaf), axis),
            self._step_params)
        c_specs = tree_map_with_path(
            lambda path, leaf: shd.tp_cache_pspec(path[-1].key,
                                                  jnp.ndim(leaf), axis),
            jax.eval_shape(self._init_slot_cache))
        in_specs = (p_specs, c_specs, P(), P())
        if getattr(self, "paged_kv", False):
            in_specs += (P(),)
        if n_chunks:
            in_specs += (P(), P(), P(), P())
        out_specs = (P(), c_specs) + ((P(),) if n_chunks else ())
        return in_specs, out_specs

    def _wave_state(self, params, cache, x):
        """Wavefront form: the scalar wave position broadcasts into the
        per-slot (B,) position vector the program contract expects; every
        wavefront slot decodes, so the scatter mask is all-true."""
        pos = jnp.full((self.batch,), cache["pos"], jnp.int32)
        return self._slot_state(params, cache, x, pos,
                                jnp.ones((self.batch,), bool))

    def _coprefill_to_ffn_in(self, params, pf_tokens, P: int, pf_rows: int):
        """Run a riding prompt's prefill up to the FFN in-projection input
        — the part that precedes the fused launch.  pf_tokens: (Bp, P).
        Returns (pf_h2 (pf_rows, d) zero-padded, xm post-attention hidden
        (Bp, P, d), kp, vp (Bp, P, Hkv, D))."""
        from repro.models import layers

        cfg = self.cfg
        run = lm.layer_runs(cfg)[0]
        p = params[run.name]
        xp, _ = lm._embed_inputs(cfg, params, {"tokens": pf_tokens})
        Bp = xp.shape[0]
        hp = layers.apply_norm(cfg, p["norm1"], xp)
        qp, kp, vp = layers.qkv_project(cfg, p["attn"], hp)
        positions = jnp.arange(P)[None, :]
        qp = layers.rope(qp, positions, cfg.rope_theta, cfg.rope_fraction)
        kp = layers.rope(kp, positions, cfg.rope_theta, cfg.rope_fraction)
        op_ = layers.blockwise_attention(qp, kp, vp, causal=True)
        xm = xp + op_.reshape(Bp, P, -1) @ p["attn"]["w_o"]
        h2p = layers.apply_norm(cfg, p["norm2"], xm)
        rows = Bp * P
        pf_x = h2p.reshape(rows, cfg.d_model)
        if pf_rows != rows:
            pf_x = jnp.concatenate(
                [pf_x, jnp.zeros((pf_rows - rows, cfg.d_model), pf_x.dtype)])
        return pf_x.astype(jnp.dtype(cfg.dtype)), xm, kp, vp

    def _make_decode_step(self, prefill_len: int):
        """The jitted executed decode step (wavefront scheduling).
        ``prefill_len > 0`` is the mixed form: the pending wave's
        (B, prefill_len) prompt rides along — its FFN in-projection joins
        the fused launch, the rest of its prefill completes here, and the
        returned (cache, logits) seed that wave's decode without ever
        calling ``lm.prefill``."""
        from repro.models import layers

        cfg = self.cfg
        B, d = self.batch, cfg.d_model
        run = lm.layer_runs(cfg)[0]
        S = self._aligned_len()
        P = prefill_len
        rows = B * P
        pf_rows = self.prefill_budget.pad_rows(rows)
        program = self.build_decode_program(ffn_rows=pf_rows if P else 0)

        def step(params, cache, tokens, pf_tokens=None):
            p = params[run.name]
            x = layers.embed_onehot(params["embed"], tokens[:, None], d)
            state = self._wave_state(params, cache, x[:, 0])

            if P:
                # pending wave's prefill, up to the FFN in-projection
                state["pf_h2"], xm, kp, vp = self._coprefill_to_ffn_in(
                    params, pf_tokens, P, pf_rows)

            state = program(state)

            xf = layers.apply_norm(cfg, params["final_norm"],
                                   state["x_out"][:, None, :].astype(x.dtype))
            logits = lm._head(cfg, params, xf)[:, 0]
            new_cache = {"pos": cache["pos"] + 1,
                         run.name: {"k": state["k_cache"],
                                    "v": state["v_cache"]}}
            if not P:
                return logits, new_cache

            ff = _mlp_from_h(cfg, state["pf_ffn"][:rows]
                             .astype(jnp.dtype(cfg.dtype)).reshape(B, P, -1),
                             p["mlp"]["w_out"])
            xop = xm + ff
            kc = jnp.zeros((B, S) + kp.shape[2:], kp.dtype)
            vc = jnp.zeros_like(kc)
            pf_cache = {"pos": jnp.asarray(P, jnp.int32),
                        run.name: {
                            "k": jax.lax.dynamic_update_slice(
                                kc, kp, (0, 0, 0, 0)),
                            "v": jax.lax.dynamic_update_slice(
                                vc, vp, (0, 0, 0, 0))}}
            xfp = layers.apply_norm(cfg, params["final_norm"], xop[:, -1:])
            pf_logits = lm._head(cfg, params, xfp)[:, 0]
            return logits, new_cache, pf_cache, pf_logits

        return step

    def _mixed_step(self, prefill_len: int):
        if prefill_len not in self._mixed_steps:
            self._mixed_steps[prefill_len] = jax.jit(
                self._make_decode_step(prefill_len))
        return self._mixed_steps[prefill_len]

    # ------------------------------------------------------------------
    # Continuous batching: per-slot cache positions, admit/refill per token
    # ------------------------------------------------------------------
    def _init_slot_cache(self):
        """The slot cache: ``lm.init_cache`` with the scalar wave position
        replaced by the per-slot position vector (B,).  Paged: the k/v
        leaves are the flat ``(kv_blocks, block_size, Hkv, D)`` arena the
        block tables index into, not per-slot regions."""
        if getattr(self, "paged_kv", False):
            run = lm.layer_runs(self.cfg)[0]
            dt = jnp.dtype(self.cfg.dtype)
            shape = (self.kv_blocks, self.kv_block_size,
                     self.cfg.num_kv_heads, self.cfg.resolved_head_dim)
            return {"pos": jnp.zeros((self.batch,), jnp.int32),
                    run.name: {"k": jnp.zeros(shape, dt),
                               "v": jnp.zeros(shape, dt)}}
        cache = lm.init_cache(self.cfg, self.batch, self.cache_len)
        cache["pos"] = jnp.zeros((self.batch,), jnp.int32)
        return cache

    def _slot_axes(self):
        """vmap axes pytree for the slot cache: batch lives on axis 0 of
        plain run leaves and axis 1 of scan-stacked (layer-major) leaves."""
        axes = {"pos": 0}
        for run in lm.layer_runs(self.cfg):
            leaves = lm._cache_leaf_shapes(self.cfg, run, 1, self.cache_len)
            axes[run.name] = {name: (1 if run.count > 1 else 0)
                              for name in leaves}
        return axes

    def _cb_plain_decode(self):
        """Generic continuous decode: ``lm.decode_step`` vmapped over slots,
        each at its own cache position — works for EVERY config (stacked
        runs, MoE, recurrent caches), not just the executable shape."""
        if self._cb_decode is None:
            cfg = self.cfg
            runs = lm.layer_runs(cfg)
            axes = self._slot_axes()

            def one(params, cache_b, tok):
                # vmap stripped the slot axis — restore the B=1 batch dim
                # lm.decode_step expects (pos stays a per-slot scalar)
                full = {"pos": cache_b["pos"]}
                for run in runs:
                    ax = 1 if run.count > 1 else 0
                    full[run.name] = {k: jnp.expand_dims(v, ax)
                                      for k, v in cache_b[run.name].items()}
                logits, newc = lm.decode_step(cfg, params, full, tok[None])
                out = {"pos": newc["pos"]}
                for run in runs:
                    ax = 1 if run.count > 1 else 0
                    out[run.name] = {k: jnp.squeeze(v, ax)
                                     for k, v in newc[run.name].items()}
                return logits[0], out

            def step(params, cache, tokens, active):
                logits, newc = jax.vmap(
                    one, in_axes=(None, axes, 0),
                    out_axes=(0, axes))(params, cache, tokens)
                # inactive slots hold their position (their writes land one
                # past their retired prefix — masked, and overwritten by the
                # next refill before they could ever become visible)
                newc["pos"] = jnp.where(active, newc["pos"], cache["pos"])
                return logits, newc

            self._cb_decode = jax.jit(step)
        return self._cb_decode

    def _cb_refill(self, cache, slot, prompt):
        """Admit one prompt into a free slot: prefill (1, P), write the
        cache leaves into the slot's rows, set its position to P.  Returns
        (cache, last-token logits (V,))."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
        c1, logits = self._prefill(self.params, {"tokens": toks})
        if self._refill_write is None:
            runs = lm.layer_runs(self.cfg)

            def write(cache, c1, slot):
                new = {"pos": cache["pos"].at[slot]
                       .set(c1["pos"].astype(jnp.int32))}
                for run in runs:
                    if run.count > 1:
                        new[run.name] = {
                            k: cache[run.name][k].at[:, slot]
                            .set(c1[run.name][k][:, 0])
                            for k in cache[run.name]}
                    else:
                        new[run.name] = {
                            k: cache[run.name][k].at[slot]
                            .set(c1[run.name][k][0])
                            for k in cache[run.name]}
                return new

            self._refill_write = jax.jit(write)
        return self._refill_write(cache, c1, jnp.asarray(slot)), logits[0]

    def _make_cb_step(self, n_chunks: int):
        """The jitted executed continuous step: decode every slot at its own
        cache position; with ``n_chunks > 0``, that many prompt chunks from
        *prefilling* slots ride along.  Each chunk's k/v is scattered into
        its slot's cache rows before the program runs, its flash-prefill
        attention shares the decode launch (the steady mixed
        prefill⊕decode bundle), and the chunk's FFN + residuals finish
        after the program.  The final chunk's last valid row yields the
        request's first-token logits.

        Stacked configs (one ATTN run with ``count > 1``) scan the
        per-layer body over the layer-stacked param/cache leaves — the
        program runs once per layer inside ``lax.scan``, carrying the
        decode hidden (B, d) and each chunk's (C, d) hidden between
        layers.  Under tensor parallelism the whole step body runs inside
        ``compat.shard_map``: every shard executes its own shard-local
        fused program, the output projections psum, and logits/positions
        come out replicated."""
        from repro.models import layers
        from repro.runtime_flags import maybe_scan

        cfg = self.cfg
        B, d = self.batch, cfg.d_model
        run = lm.layer_runs(cfg)[0]
        L = run.count
        dt = jnp.dtype(cfg.dtype)
        n = n_chunks
        tp = self.tp_shards
        axis = self.shard_axis
        H_l = cfg.num_heads // tp
        Hkv_l = cfg.num_kv_heads // tp
        D = cfg.resolved_head_dim
        paged = getattr(self, "paged_kv", False)
        bs = self.kv_block_size if paged else 0
        C = self.prefill_budget.effective_chunk(
            self.cache_len if paged else self._aligned_len(),
            multiple=bs if paged else 1)
        program = self.build_decode_program(prefill_chunks=n)
        # a chunk counts as fused when it shares a launch with any
        # decode-side member — decode attention OR the stitched FFN chain
        # (with epilogue stitching the planner's second bundle pairs a chunk
        # with ffn_proj→decode_act, which is just as much a mixed launch)
        self._cb_fused_chunks[n] = frozenset(
            i for i in range(n)
            if any(any(m.startswith(f"prefill_attn{i}_") for m in ms)
                   and any(not m.startswith("prefill_attn") for m in ms)
                   for ms in program.fused_members))
        self.cb_program_info[n] = {
            "fused_launches": program.n_fused,
            "total_launches": len(program.steps),
            "fused_members": [sorted(ms) for ms in program.fused_members],
            "steps": program.describe(),
        }
        is_moe = cfg.moe is not None

        def layer_step(p, kv, x, pos, act, bt, chs, ch_slots, ch_offs):
            """One transformer layer over the whole slot state: the decode
            step for all B slots plus the riding chunks' pre/post-work.
            ``chs`` is the tuple of per-chunk (C, d) hiddens this layer
            consumes and reproduces (the scan carry)."""
            state = self._layer_state(p, kv, x, pos, act)
            if paged:
                state["bt"] = bt              # (B, max_blocks) int32 tables

            # chunk pre-work: norm + QKV + RoPE at absolute chunk
            # positions, then land the chunk's k/v in its slot's cache rows
            # BEFORE the program (the prefill kernel only reads the cache).
            # The QKV split uses shard-local head counts — under TP the
            # weight slab arrives permuted to [q_s|k_s|v_s], so the plain
            # contiguous slicing below is exactly layers.qkv_project on
            # this shard's heads.  Paged: chunk offsets are chunk-aligned
            # (admission floors prefix reuse to whole chunks), so the
            # chunk covers exactly C // bs whole pages — gather their
            # arena blocks from the slot's table row and scatter page by
            # page.
            kc, vc = state["k_cache"], state["v_cache"]
            for i in range(n):
                xp = chs[i][None]                              # (1, C, d)
                hp = layers.apply_norm(cfg, p["norm1"], xp)
                qkv = hp @ p["attn"]["w_qkv"]
                qp = qkv[..., :H_l * D].reshape(1, C, H_l, D)
                kp = qkv[..., H_l * D:(H_l + Hkv_l) * D] \
                    .reshape(1, C, Hkv_l, D)
                vp = qkv[..., (H_l + Hkv_l) * D:].reshape(1, C, Hkv_l, D)
                positions = ch_offs[i] + jnp.arange(C)[None, :]
                qp = layers.rope(qp, positions, cfg.rope_theta,
                                 cfg.rope_fraction)
                kp = layers.rope(kp, positions, cfg.rope_theta,
                                 cfg.rope_fraction)
                if paged:
                    npg = C // bs
                    blks = jax.lax.dynamic_slice(
                        bt, (ch_slots[i], ch_offs[i] // bs), (1, npg))[0]
                    kc = kc.at[blks].set(
                        kp[0].reshape(npg, bs, *kp.shape[2:]).astype(kc.dtype))
                    vc = vc.at[blks].set(
                        vp[0].reshape(npg, bs, *vp.shape[2:]).astype(vc.dtype))
                else:
                    kc = jax.lax.dynamic_update_slice(
                        kc, kp.astype(kc.dtype),
                        (ch_slots[i], ch_offs[i], 0, 0))
                    vc = jax.lax.dynamic_update_slice(
                        vc, vp.astype(vc.dtype),
                        (ch_slots[i], ch_offs[i], 0, 0))
                state[f"pf{i}_q"] = qp[0].astype(dt)
                state[f"pf{i}_slot"] = ch_slots[i]
                state[f"pf{i}_off"] = jnp.reshape(ch_offs[i],
                                                  (1, 1)).astype(jnp.int32)
            state["k_cache"], state["v_cache"] = kc, vc

            state = program(state)

            # chunk post-work: W_o + residual, norm2 + MLP + residual —
            # the chunk leaves this layer as its next (C, d) hidden.
            # Under TP both output projections are row-sharded partials.
            new_chs = []
            for i in range(n):
                o = state[f"pf{i}_o"].astype(dt)             # (C, H_l, D)
                attn_out = o.reshape(C, -1) @ p["attn"]["w_o"]
                if tp > 1:
                    attn_out = jax.lax.psum(attn_out, axis)
                xm = chs[i] + attn_out
                h2 = layers.apply_norm(cfg, p["norm2"], xm[None])
                if is_moe:
                    # chunk rows route jointly (T = C), same jnp path as
                    # the fallback's whole-prompt prefill — at the serving
                    # capacities in play (capacity(cfg, C) >= C) neither
                    # batching ever drops a token, so outputs are exact
                    ff = lm._apply_ffn(cfg, p, h2, True)[0][0]
                else:
                    ff = _mlp_from_h(cfg, h2[0] @ p["mlp"]["w_in"],
                                     p["mlp"]["w_out"])
                if tp > 1:
                    ff = jax.lax.psum(ff, axis)
                new_chs.append(xm + ff)
            ret = (state["x_out"],
                   {"k": state["k_cache"], "v": state["v_cache"]},
                   tuple(new_chs))
            if is_moe:
                ret += (state["expert_counts"],)
            return ret

        def core(params, cache, tokens, active, *rest):
            rest = list(rest)
            bt = rest.pop(0) if paged else None
            ch_slots = ch_offs = ch_valid = ch_tokens = None
            if n:
                ch_slots, ch_offs, ch_valid, ch_tokens = rest
            x = layers.embed_onehot(params["embed"], tokens[:, None], d)
            chs = tuple(
                lm._embed_inputs(cfg, params,
                                 {"tokens": ch_tokens[i][None]})[0][0]
                for i in range(n))
            pos = cache["pos"]
            ecounts = None
            if L == 1:
                out = layer_step(
                    params[run.name], cache[run.name], x[:, 0], pos,
                    active, bt, chs, ch_slots, ch_offs)
                if is_moe:
                    x1, kv_new, chs, ecounts = out
                else:
                    x1, kv_new, chs = out
            elif is_moe:
                # the scan carries a per-expert hit accumulator so the
                # host sees layer-summed counts per step
                def body(carry, xs):
                    xc, chc, cnt = carry
                    p_l, kv_l = xs
                    xn, kv_out, chn, c_l = layer_step(p_l, kv_l, xc, pos,
                                                      active, bt, chc,
                                                      ch_slots, ch_offs)
                    return (xn, chn, cnt + c_l), kv_out
                (x1, chs, ecounts), kv_new = maybe_scan(
                    body, (x[:, 0], chs,
                           jnp.zeros((cfg.moe.num_experts,), jnp.int32)),
                    (params[run.name], cache[run.name]), length=L)
            else:
                def body(carry, xs):
                    xc, chc = carry
                    p_l, kv_l = xs
                    xn, kv_out, chn = layer_step(p_l, kv_l, xc, pos,
                                                 active, bt, chc,
                                                 ch_slots, ch_offs)
                    return (xn, chn), kv_out
                (x1, chs), kv_new = maybe_scan(
                    body, (x[:, 0], chs),
                    (params[run.name], cache[run.name]), length=L)

            xf = layers.apply_norm(cfg, params["final_norm"],
                                   x1[:, None, :].astype(x.dtype))
            logits = lm._head(cfg, params, xf)[:, 0]
            new_pos = jnp.where(active, pos + 1, pos)
            new_cache = {"pos": new_pos, run.name: kv_new}
            moe_tail = (ecounts,) if is_moe else ()
            if not n:
                return (logits, new_cache) + moe_tail

            # the (possibly partial) chunk's last valid row -> first-token
            # logits; positions advance by the chunk's valid rows
            pf_logits = []
            for i in range(n):
                xlast = jax.lax.dynamic_slice_in_dim(chs[i],
                                                     ch_valid[i] - 1, 1)
                xfp = layers.apply_norm(cfg, params["final_norm"],
                                        xlast[None])
                pf_logits.append(lm._head(cfg, params, xfp)[0, 0])
                new_pos = new_pos.at[ch_slots[i]].set(ch_offs[i]
                                                      + ch_valid[i])
            new_cache["pos"] = new_pos
            return (logits, new_cache, jnp.stack(pf_logits)) + moe_tail

        if tp > 1:
            from repro.distributed.compat import shard_map
            in_specs, out_specs = self._tp_specs(n)
            # fully-manual SPMD: every shard traces the same program over
            # its slab; logits come out replicated (both projections psum
            # before anything data-dependent), so sampling stays host-side
            # and shard-invariant.  check_vma=False: the 0.4.x fallback
            # cannot prove replication through the Pallas calls.
            core = shard_map(core, mesh=self.mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=(axis,),
                             check_vma=False)

        def step(params, cache, tokens, active, bt=None,
                 ch_slots=None, ch_offs=None, ch_valid=None, ch_tokens=None):
            args = (params, cache, tokens, active)
            if paged:
                args += (bt,)
            if n:
                args += (ch_slots, ch_offs, ch_valid, ch_tokens)
            return core(*args)

        return step

    def _cb_step(self, n_chunks: int):
        if n_chunks not in self._cb_steps:
            self._cb_steps[n_chunks] = jax.jit(
                self._make_cb_step(n_chunks))
        return self._cb_steps[n_chunks]

    # ------------------------------------------------------------------
    def _wave_tokens(self, wave: list[Request]) -> np.ndarray:
        S = len(wave[0].prompt)
        toks = np.zeros((self.batch, S), np.int32)
        for i, r in enumerate(wave):
            toks[i] = r.prompt
        return toks

    def _prefill_wave(self, wave: list[Request]):
        """Waves are grouped by prompt length (see run()); empty slots
        duplicate row 0 and are ignored."""
        toks = self._wave_tokens(wave)
        cache, last_logits = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        return cache, last_logits

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        if req.temperature > 0:
            self.rng, sub = jax.random.split(self.rng)
            return int(jax.random.categorical(
                sub, jnp.asarray(logits) / req.temperature))
        return int(logits.argmax())

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        if self.scheduling == "continuous":
            return self._run_continuous(requests)
        return self._run_wavefront(requests)

    # ------------------------------------------------------------------
    def _retire_reason(self, req: Request, tok: int, n_out: int, pos: int, *,
                       check_eos: bool = True) -> Optional[str]:
        """Retirement rule over explicit (n_out, pos) so the same-step
        refill predictor evaluates it on post-step values — prediction and
        reality cannot desync."""
        if check_eos and req.eos_token is not None and tok == req.eos_token:
            return "eos"
        if n_out >= req.max_new_tokens:
            return "max_new"
        if pos >= self.cache_len:
            return "max_len"                 # cache full: truncate
        return None

    def _will_retire_this_step(self, req: Request, pos_now: int) -> bool:
        """Deterministic retirement predictor: a decode step always lands
        one token and advances the position by one; EOS is data-dependent
        and deliberately excluded."""
        return self._retire_reason(req, -1, len(req.out_tokens) + 1,
                                   pos_now + 1, check_eos=False) is not None

    def _admit(self, req: Request, slot: int, pf_logits, slots, pos_h, last):
        """First token from the prompt's last-position logits; the slot goes
        active unless the request already retires (budget 1 / cache full).
        EOS is deliberately NOT checked here: the wavefront oracle only
        honours EOS on decode-loop tokens, never on the prefill-sampled
        first token, and the differential harness pins that behaviour."""
        stats = self.stats
        tok = self._sample(np.asarray(pf_logits, np.float32), req)
        req.out_tokens.append(tok)
        stats.tokens += 1
        stats.admissions.append((stats.steps - 1, req.rid, slot))
        stats.admission_latencies.append(stats.steps - 1 - req.arrival)
        pos_h[slot] = len(req.prompt)
        reason = self._retire_reason(req, tok, len(req.out_tokens),
                                     pos_h[slot], check_eos=False)
        if reason:
            req.done = True
            stats.retirements.append((stats.steps - 1, req.rid, reason))
        else:
            assert slots[slot] is None, \
                f"slot {slot} refilled while request {slots[slot].rid} lives"
            slots[slot] = req
            last[slot] = tok

    def _run_continuous(self, requests: list[Request]) -> list[Request]:
        """Iteration-level continuous batching.  Prompts longer than the
        cache can never be admitted; with ``reject_overlong=True`` the
        legacy single-iteration admission contract is restored and prompts
        exceeding one iteration's prefill budget are rejected too.  The
        executed path admits by chunks (``_run_continuous_chunked``); the
        hand-wired fallback prefills whole prompts alongside the decode
        (``_run_continuous_plain``)."""
        paged = getattr(self, "paged_kv", False)
        chunk = self.prefill_budget.effective_chunk(
            self.cache_len if paged else self._aligned_len(),
            multiple=self.kv_block_size if paged else 1)
        for r in requests:
            if len(r.prompt) > self.cache_len:
                raise ValueError(
                    f"request {r.rid}: prompt length {len(r.prompt)} exceeds "
                    f"max_seq_len {self.cache_len} — continuous batching "
                    "cannot admit it (raise max_len"
                    + (" or kv_slot_blocks" if paged else "")
                    + " or truncate the prompt)")
            if self.reject_overlong and len(r.prompt) > chunk:
                raise ValueError(
                    f"request {r.rid}: prompt length {len(r.prompt)} exceeds "
                    f"the per-iteration prefill budget {chunk} and this "
                    f"engine was built with reject_overlong=True (drop the "
                    f"flag to admit it in chunks)")
        self.stats = ServeStats(batch=self.batch)
        # FIFO by arrival step, submission order breaking ties
        waiting = sorted(requests, key=lambda r: r.arrival)
        if self.executed:
            return self._run_continuous_chunked(requests, waiting)
        return self._run_continuous_plain(requests, waiting)

    def _run_continuous_chunked(self, requests, waiting) -> list[Request]:
        """Executed continuous batching with chunk-granular admission:
        every step decodes all active slots at their own cache positions
        while up to ``max_coresident_chunks`` *prefilling* slots each
        consume one prompt chunk inside the same fused launch.  A freshly
        emptied slot's first chunk rides the very step it is claimed; a
        slot whose occupant retires deterministically this step is reserved
        and starts chunking the next step (its retiree's final decode must
        read the cache first).  A prompt completing its last chunk samples
        its first token from that chunk's final valid row."""
        B = self.batch
        stats = self.stats
        budget = self.prefill_budget
        pool = self.kv_pool
        paged = pool is not None
        is_moe = self.cfg.moe is not None
        C = budget.effective_chunk(
            self.cache_len if paged else self._aligned_len(),
            multiple=self.kv_block_size if paged else 1)
        if paged:
            # the pool persists across runs (prefix cache survives); this
            # run's stats report the deltas
            pool_base = (pool.evictions, pool.prefix_hits,
                         pool.prefix_tokens_reused)
        slots: list[Optional[Request]] = [None] * B   # decoding occupants
        pref: dict[int, dict] = {}                    # slot -> prefilling
        #                                               {req, done, ready}
        pos_h = [0] * B                               # host mirror of pos
        last = np.zeros(B, np.int32)
        cache = self._init_slot_cache()

        def claim(b, req, now):
            """Start prefilling ``req`` in slot ``b``.  Paged: allocate its
            table row, and let a prefix-cache hit skip whole chunks —
            ``done`` starts at the reused token count, not 0."""
            ent = {"req": req, "done": 0, "ready": now}
            if paged:
                ent["done"] = pool.admit(b, req.prompt, C, now)
                stats.prompt_tokens += len(req.prompt)
            pref[b] = ent

        while waiting or any(s is not None for s in slots) or pref:
            step_i = stats.steps
            arrived = [r for r in waiting if r.arrival <= step_i]
            # claim empty slots now (their first chunk rides this very
            # step); deterministically-retiring slots are only *reserved*
            # — their chunk starts next step, after the retiree's final
            # decode has read the cache (EOS retirements are not
            # predictable; those slots are claimed one step later)
            reserved = []
            for b in range(B):
                if not arrived:
                    break
                if slots[b] is None and b not in pref:
                    req = arrived.pop(0)
                    waiting.remove(req)
                    claim(b, req, step_i)
            for b in range(B):
                if not arrived:
                    break
                if slots[b] is not None and self._will_retire_this_step(
                        slots[b], pos_h[b]):
                    req = arrived.pop(0)
                    waiting.remove(req)
                    reserved.append((b, req))
            # chunk selection, capped by the budget's co-residency.
            # fifo: lowest prefilling slot index first (legacy order).
            # srpf: shortest-remaining-prefill-first — the prompt with the
            # fewest chunks left to consume goes first, so near-done
            # requests admit (emit their first token) without queuing
            # behind a long prompt's tail; slot index breaks ties, keeping
            # the schedule deterministic.
            sel = [b for b in sorted(pref) if pref[b]["ready"] <= step_i]
            if budget.policy in ("srpf", "eload"):
                sel.sort(key=lambda b: (len(pref[b]["req"].prompt)
                                        - pref[b]["done"], b))
            sel = sel[:budget.max_coresident_chunks]
            # eload: when the running expert-hit skew says a few hot
            # experts dominate the decode side's weight streaming, shed
            # one coresident chunk this step — the fused launch narrows
            # so the memory phase the hot experts already saturate isn't
            # stretched further by an extra prefill partner
            if (budget.policy == "eload" and len(sel) > 1
                    and self.stats.expert_skew >= budget.skew_threshold):
                sel = sel[:-1]
                stats.load_shed_steps += 1
            if paged:
                # map the chunk's pages before its scatter; a chunk the
                # arena cannot back this step (even after eviction) simply
                # stalls — admission degrades gracefully, never crashes
                sel = [b for b in sel
                       if pool.ensure_rows(b, pref[b]["done"],
                                           pref[b]["done"] + C, step_i)]
                # each decoding slot writes one token row this step; a slot
                # the pool cannot extend retires truncated (mirrors the
                # contiguous cache-full rule, under dynamic pressure)
                for b in range(B):
                    if slots[b] is None:
                        continue
                    if not pool.ensure_rows(b, pos_h[b], pos_h[b] + 1,
                                            step_i):
                        req = slots[b]
                        req.done = True
                        slots[b] = None
                        pool.release(b)
                        stats.retirements.append((step_i, req.rid,
                                                  "pool_full"))
            active = np.array([s is not None for s in slots])
            n_active = int(active.sum())
            n = len(sel)

            if n == 0 and n_active == 0:
                ready = [b for b in pref if pref[b]["ready"] <= step_i]
                if paged and ready:
                    # arena deadlock: every schedulable chunk stalled with
                    # no decoder left to drain blocks — fail the prompt
                    # with the most work remaining (deterministic) so its
                    # partial allocation frees the others
                    b = max(ready, key=lambda b: (len(pref[b]["req"].prompt)
                                                  - pref[b]["done"], b))
                    req = pref.pop(b)["req"]
                    req.done = True
                    pool.release(b)
                    stats.retirements.append((step_i, req.rid, "pool_full"))
                stats.steps += 1                 # idle: future arrivals
                continue
            if paged:
                bt_dev = jnp.asarray(np.asarray(pool.table, np.int32))
                stats.blocks_in_use = max(stats.blocks_in_use,
                                          pool.blocks_in_use)

            if n:
                ch_valid = [min(C, len(pref[b]["req"].prompt)
                                - pref[b]["done"]) for b in sel]
                ch_tok = np.zeros((n, C), np.int32)
                for j, b in enumerate(sel):
                    off = pref[b]["done"]
                    ch_tok[j, :ch_valid[j]] = np.asarray(
                        pref[b]["req"].prompt[off:off + ch_valid[j]],
                        np.int32)
                ret = self._cb_step(n)(
                    self._step_params, cache, jnp.asarray(last),
                    jnp.asarray(active),
                    *((bt_dev,) if paged else ()),
                    ch_slots=jnp.asarray(np.asarray(sel, np.int32)),
                    ch_offs=jnp.asarray(
                        np.asarray([pref[b]["done"] for b in sel],
                                   np.int32)),
                    ch_valid=jnp.asarray(np.asarray(ch_valid, np.int32)),
                    ch_tokens=jnp.asarray(ch_tok))
                if is_moe:
                    logits, cache, pf_logits, ecounts = ret
                else:
                    logits, cache, pf_logits = ret
            else:
                ret = self._cb_step(0)(
                    self._step_params, cache, jnp.asarray(last),
                    jnp.asarray(active),
                    *((bt_dev,) if paged else ()))
                if is_moe:
                    logits, cache, ecounts = ret
                else:
                    logits, cache = ret
            if is_moe:
                stats.add_expert_hits(np.asarray(ecounts))

            stats.steps += 1
            if n_active:
                stats.decode_steps += 1
                stats.slot_steps += n_active
            else:
                stats.prefill_only_steps += 1
            if n and n_active:
                stats.mixed_steps += 1
                if self._cb_fused_chunks[n]:
                    stats.fused_mixed_steps += 1
            if n:
                stats.prefill_chunks += n
                stats.fused_prefill_chunks += len(self._cb_fused_chunks[n])

            logits_np = np.asarray(logits, np.float32)
            for b in range(B):
                req = slots[b]
                if req is None:
                    continue
                pos_h[b] += 1
                tok = self._sample(logits_np[b], req)
                req.out_tokens.append(tok)
                stats.tokens += 1
                last[b] = tok
                reason = self._retire_reason(req, tok, len(req.out_tokens),
                                             pos_h[b])
                if reason:
                    req.done = True
                    slots[b] = None
                    if paged:
                        pool.release(b)
                    stats.retirements.append((stats.steps - 1, req.rid,
                                              reason))
            if n:
                pf_np = np.asarray(pf_logits, np.float32)
                for j, b in enumerate(sel):
                    ent = pref[b]
                    ent["done"] += ch_valid[j]
                    pos_h[b] = ent["done"]
                    if ent["done"] >= len(ent["req"].prompt):
                        del pref[b]                    # prefill complete
                        if paged:
                            # the prompt is fully in cache: index its full
                            # blocks so later prompts sharing the prefix
                            # skip those chunks
                            pool.register(b, ent["req"].prompt, step_i)
                        self._admit(ent["req"], b, pf_np[j], slots, pos_h,
                                    last)
                        if paged and slots[b] is None:
                            pool.release(b)       # admitted-and-retired
            for b, req in reserved:
                # the retiree's final decode ran this step (and, paged, its
                # blocks were just released) — claim now, chunk next step
                claim(b, req, stats.steps)
        if paged:
            stats.evictions = pool.evictions - pool_base[0]
            stats.prefix_hits = pool.prefix_hits - pool_base[1]
            stats.prefix_tokens_reused = (pool.prefix_tokens_reused
                                          - pool_base[2])
        return requests

    def _run_continuous_plain(self, requests, waiting) -> list[Request]:
        """Fallback continuous batching (hand-wired decode): every step
        decodes all active slots, retires finished slots, and refills EVERY
        free slot from the arrival queue — lowest free slot first, arrival
        order first (deterministic refill given a fixed arrival queue).
        Whole prompts prefill alongside the decode in the same iteration; a
        slot whose request retires deterministically this step (budget /
        cache-full) refills in that same iteration."""
        B = self.batch
        stats = self.stats
        slots: list[Optional[Request]] = [None] * B
        pos_h = [0] * B                               # host mirror of pos
        last = np.zeros(B, np.int32)
        cache = self._init_slot_cache()

        while waiting or any(s is not None for s in slots):
            step_i = stats.steps
            # a slot is refillable when empty OR when its request retires
            # *deterministically* this very step (budget / cache-full): the
            # retiring slot's last decode reads the cache before the
            # refill's prefill rows land, so the new prompt co-prefills in
            # the same iteration (EOS retirements are not predictable;
            # those slots refill one step later)
            free = [i for i, s in enumerate(slots)
                    if s is None or self._will_retire_this_step(s, pos_h[i])]
            arrived = [r for r in waiting if r.arrival <= step_i]
            refills = list(zip(free, arrived))
            for _slot, r in refills:
                waiting.remove(r)
            active = np.array([s is not None for s in slots])
            n_active = int(active.sum())

            if n_active == 0:
                stats.steps += 1
                if not refills:
                    continue                          # idle: future arrivals
                stats.prefill_only_steps += 1
                for slot, req in refills:
                    cache, pf_logits = self._cb_refill(cache, slot,
                                                       req.prompt)
                    self._admit(req, slot, pf_logits, slots, pos_h, last)
                continue

            logits, cache = self._cb_plain_decode()(
                self.params, cache, jnp.asarray(last), jnp.asarray(active))
            extra_logits = []
            for slot, req in refills:     # side-by-side prefills
                cache, pf_logits = self._cb_refill(cache, slot, req.prompt)
                extra_logits.append(pf_logits)
            stats.steps += 1
            stats.decode_steps += 1
            stats.slot_steps += n_active
            if refills:
                stats.mixed_steps += 1

            logits_np = np.asarray(logits, np.float32)
            for b in range(B):
                req = slots[b]
                if req is None:
                    continue
                pos_h[b] += 1
                tok = self._sample(logits_np[b], req)
                req.out_tokens.append(tok)
                stats.tokens += 1
                last[b] = tok
                reason = self._retire_reason(req, tok, len(req.out_tokens),
                                             pos_h[b])
                if reason:
                    req.done = True
                    slots[b] = None
                    stats.retirements.append((stats.steps - 1, req.rid,
                                              reason))
            for (slot, req), pf_logits in zip(refills, extra_logits):
                self._admit(req, slot, pf_logits, slots, pos_h, last)
        return requests

    # ------------------------------------------------------------------
    def _run_wavefront(self, requests: list[Request]) -> list[Request]:
        # group by prompt length: one wave = one (length, <=batch) group
        by_len: dict[int, list[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        pending: list[list[Request]] = []
        for _, group in sorted(by_len.items()):
            for i in range(0, len(group), self.batch):
                pending.append(group[i: i + self.batch])
        carried = None              # (cache, logits) co-prefilled for pending[0]
        while pending:
            wave = pending.pop(0)
            if carried is not None:
                cache, last_logits = carried
                carried = None
            else:
                cache, last_logits = self._prefill_wave(wave)
            logits = np.asarray(last_logits, np.float32)
            for i, r in enumerate(wave):
                r.out_tokens.append(self._sample(logits[i], r))
            budget = max(r.max_new_tokens for r in wave)
            for step_i in range(budget - 1):
                if all(r.done or len(r.out_tokens) >= r.max_new_tokens
                       for r in wave):
                    break
                toks = np.zeros((self.batch,), np.int32)
                for i, r in enumerate(wave):
                    toks[i] = r.out_tokens[-1]
                if (self.executed and step_i == 0 and pending
                        and carried is None):
                    # chunked prefill⊕decode co-execution: the next wave's
                    # prompt FFN rides in this step's fused launch
                    nxt = pending[0]
                    out, cache, pf_cache, pf_logits = self._mixed_step(
                        len(nxt[0].prompt))(
                            self.params, cache, jnp.asarray(toks),
                            jnp.asarray(self._wave_tokens(nxt)))
                    carried = (pf_cache, pf_logits)
                else:
                    out, cache = self._decode(self.params, cache,
                                              jnp.asarray(toks))
                logits = np.asarray(out, np.float32)
                for i, r in enumerate(wave):
                    if r.done or len(r.out_tokens) >= r.max_new_tokens:
                        continue
                    tok = self._sample(logits[i], r)
                    r.out_tokens.append(tok)
                    if r.eos_token is not None and tok == r.eos_token:
                        r.done = True
            for r in wave:
                r.done = True
        return requests
