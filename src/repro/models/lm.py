"""LM assembly: block dispatch, scan-grouped layer stacks, train / prefill /
decode entry points, KV/recurrent caches, modality frontends.

Layer stacking: consecutive layers with identical (kind, is_moe) are grouped
into a *run* whose parameters are stacked on a leading 'layer' axis and
evaluated with ``lax.scan`` — one compiled block body per run regardless of
depth (compile-time and HLO-size control for the 60-layer DeepSeek dry-run).
"""
from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, LOCAL_ATTN, MLA, MLSTM, RGLRU, SLSTM,
                                ModelConfig)
from repro.distributed.sharding import shard
from repro.runtime_flags import maybe_scan
from repro.models import layers, mla as mla_mod, moe as moe_mod
from repro.models import rglru as rglru_mod, xlstm as xlstm_mod
from repro.models.base import (ParamSpec, SpecTree, abstract_params,
                               count_spec_params, init_params, logical_axes,
                               stack_specs)


# ---------------------------------------------------------------------------
# Runs
# ---------------------------------------------------------------------------
class Run(NamedTuple):
    kind: str
    is_moe: bool
    start: int
    count: int

    @property
    def name(self) -> str:
        return f"run{self.start:02d}_{self.kind}{'_moe' if self.is_moe else ''}"


def layer_runs(cfg: ModelConfig) -> list[Run]:
    runs: list[Run] = []
    for i, kind in enumerate(cfg.pattern):
        m = cfg.moe_layer(i)
        if runs and runs[-1].kind == kind and runs[-1].is_moe == m:
            runs[-1] = runs[-1]._replace(count=runs[-1].count + 1)
        else:
            runs.append(Run(kind, m, i, 1))
    return runs


# ---------------------------------------------------------------------------
# Per-block specs
# ---------------------------------------------------------------------------
def _ffn_spec(cfg: ModelConfig, is_moe: bool, layer0_dense: bool) -> dict:
    if is_moe:
        return {"moe": moe_mod.spec(cfg)}
    if cfg.d_ff == 0:
        return {}
    if layer0_dense and cfg.dense_d_ff_first:
        import dataclasses
        c = dataclasses.replace(cfg, d_ff=cfg.dense_d_ff_first)
        return {"mlp": layers.mlp_spec(c), "_dense_ff": None}
    return {"mlp": layers.mlp_spec(cfg)}


def block_spec(cfg: ModelConfig, run: Run) -> SpecTree:
    kind = run.kind
    sp: dict = {"norm1": layers.norm_spec(cfg)}
    if kind == ATTN or kind == LOCAL_ATTN:
        sp["attn"] = layers.attn_spec(cfg)
    elif kind == MLA:
        sp["attn"] = mla_mod.spec(cfg)
    elif kind == RGLRU:
        sp["rec"] = rglru_mod.spec(cfg)
    elif kind == MLSTM:
        sp["rec"] = xlstm_mod.mlstm_spec(cfg)
    elif kind == SLSTM:
        sp["rec"] = xlstm_mod.slstm_spec(cfg)
    else:
        raise ValueError(kind)
    layer0_dense = run.start == 0 and bool(cfg.dense_d_ff_first)
    ffn = _ffn_spec(cfg, run.is_moe, layer0_dense)
    ffn.pop("_dense_ff", None)
    if ffn:
        sp["norm2"] = layers.norm_spec(cfg)
        sp.update(ffn)
    return sp


# ---------------------------------------------------------------------------
# Model-level specs
# ---------------------------------------------------------------------------
def param_specs(cfg: ModelConfig) -> SpecTree:
    sp: dict = {}
    if cfg.frontend == "audio_stub":
        sp["embed"] = {"embedding": ParamSpec(
            (cfg.num_codebooks, cfg.vocab_size, cfg.d_model),
            (None, "vocab", "embed"), "embed")}
        sp["head"] = {"w": ParamSpec(
            (cfg.d_model, cfg.num_codebooks * cfg.vocab_size),
            ("embed", "vocab"))}       # K fused logit heads (horizontal fusion)
    else:
        sp["embed"] = layers.embed_spec(cfg)
        if not cfg.tie_embeddings:
            sp["head"] = {"w": ParamSpec((cfg.d_model, cfg.vocab_size),
                                         ("embed", "vocab"))}
    for run in layer_runs(cfg):
        one = block_spec(cfg, run)
        sp[run.name] = stack_specs(one, run.count) if run.count > 1 else one
    sp["final_norm"] = layers.norm_spec(cfg)
    return sp


def init(cfg: ModelConfig, key: jax.Array):
    return init_params(param_specs(cfg), key, jnp.dtype(cfg.dtype))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = count_spec_params(param_specs(cfg))
    if active_only and cfg.is_moe:
        m = cfg.moe
        per_moe_layer = count_spec_params(
            {"w_in": moe_mod.spec(cfg)["w_in"], "w_out": moe_mod.spec(cfg)["w_out"]})
        n_moe = sum(1 for i in range(cfg.num_layers) if cfg.moe_layer(i))
        inactive = n_moe * per_moe_layer * (m.num_experts - m.top_k) // m.num_experts
        total -= inactive
    return total


# ---------------------------------------------------------------------------
# Block bodies — full sequence (train / prefill)
# ---------------------------------------------------------------------------
def _apply_ffn(cfg, p, x, is_moe):
    if is_moe:
        y, aux = moe_mod.apply(cfg, p["moe"], x)
        return y, aux
    if "mlp" not in p:
        return None, 0.0
    import dataclasses
    d_ff = p["mlp"]["w_out"].shape[-2]
    c = dataclasses.replace(cfg, d_ff=int(d_ff)) if d_ff != cfg.d_ff else cfg
    return layers.mlp(c, p["mlp"], x), 0.0


def block_apply_seq(cfg, run: Run, p, x, *, want_cache: bool, max_len: int = 0):
    """Full-sequence block.  Returns (x_out, aux_loss, cache_leaf|None)."""
    kind = run.kind
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    h = layers.apply_norm(cfg, p["norm1"], x)
    cache = None

    if kind in (ATTN, LOCAL_ATTN):
        q, k, v = layers.qkv_project(cfg, p["attn"], h)
        q = layers.rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = layers.rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
        q = shard(q, ("batch", "seq", "act_heads", None))
        k = shard(k, ("batch", "seq", "act_heads", None))
        if kind == ATTN:
            o = layers.blockwise_attention(q, k, v, causal=True)
            if want_cache:
                Smax = max_len or S
                kc = jnp.zeros((B, Smax) + k.shape[2:], k.dtype)
                vc = jnp.zeros_like(kc)
                cache = {"k": jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0)),
                         "v": jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))}
        else:
            W = cfg.local_window
            o = layers.local_attention(q, k, v, W)
            if want_cache:
                # ring-buffer handoff: slot(p) = p % Wb.  Valid when S < Wb
                # (identity) or S % Wb == 0 (aligned wrap) — both hold for
                # the assigned shapes (32768 % 2048 == 0).
                Wb = min(W, max_len or S)
                if S >= Wb:
                    cache = {"k": k[:, -Wb:], "v": v[:, -Wb:]}
                else:
                    kc = jnp.zeros((B, Wb) + k.shape[2:], k.dtype)
                    cache = {"k": jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0)),
                             "v": jax.lax.dynamic_update_slice(
                                 jnp.zeros_like(kc), v, (0, 0, 0, 0))}
        attn_out = o.reshape(B, S, -1) @ p["attn"]["w_o"]
    elif kind == MLA:
        attn_out, (latent, k_rope) = mla_mod.attend_full(cfg, p["attn"], h, positions)
        if want_cache:
            Smax = max_len or S
            lc = jnp.zeros((B, Smax, latent.shape[-1]), latent.dtype)
            rc = jnp.zeros((B, Smax, k_rope.shape[-1]), k_rope.dtype)
            cache = {"latent": jax.lax.dynamic_update_slice(lc, latent, (0, 0, 0)),
                     "rope": jax.lax.dynamic_update_slice(rc, k_rope, (0, 0, 0))}
    elif kind == RGLRU:
        attn_out, (h_last, conv_tail) = rglru_mod.apply_train(cfg, p["rec"], h)
        if want_cache:
            cache = {"h": h_last, "conv": conv_tail}
    elif kind == MLSTM:
        attn_out, (state, conv_tail) = xlstm_mod.mlstm_apply_train(cfg, p["rec"], h)
        if want_cache:
            cache = {"C": state[0], "n": state[1], "m": state[2], "conv": conv_tail}
    elif kind == SLSTM:
        attn_out, (state, conv_tail) = xlstm_mod.slstm_apply_train(cfg, p["rec"], h)
        if want_cache:
            cache = {"c": state[0], "n": state[1], "m": state[2],
                     "h": state[3], "conv": conv_tail}
    else:
        raise ValueError(kind)

    x = x + attn_out
    x = shard(x, ("batch", "seq", "embed"))
    ff, aux = _apply_ffn(cfg, p, layers.apply_norm(cfg, p["norm2"], x)
                         if "norm2" in p else x, run.is_moe)
    if ff is not None:
        x = x + ff
        x = shard(x, ("batch", "seq", "embed"))
    return x, jnp.asarray(aux, jnp.float32), cache


# ---------------------------------------------------------------------------
# Block bodies — single-token decode
# ---------------------------------------------------------------------------
def block_apply_decode(cfg, run: Run, p, x, cache, pos):
    """x: (B,1,d); pos: () int32 — index of the token being generated.
    Returns (x_out, new_cache_leaf)."""
    kind = run.kind
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    h = layers.apply_norm(cfg, p["norm1"], x)

    if kind in (ATTN, LOCAL_ATTN):
        q, k, v = layers.qkv_project(cfg, p["attn"], h)
        q = layers.rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = layers.rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
        # match the cache's canonical layout BEFORE the write: the fused-QKV
        # projection leaves k/v sharded on the (qkv@model) feature dim, which
        # would propagate into the cache and force a full-cache re-gather
        # every layer every step (measured 16 MB x 8 layers/step on
        # recurrentgemma decode_32k — §Perf iteration 7).
        cache_ax = ("batch", None, None, None)
        k = shard(k, cache_ax)
        v = shard(v, cache_ax)
        if kind == ATTN:
            kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
            o = layers.decode_attention(q, kc, vc, pos + 1)
            new_cache = {"k": kc, "v": vc}
        else:
            W = cache["k"].shape[1]
            slot = pos % W
            kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
            o = layers.decode_attention(q, kc, vc, jnp.minimum(pos + 1, W))
            new_cache = {"k": kc, "v": vc}
        attn_out = o.reshape(B, 1, -1) @ p["attn"]["w_o"]
    elif kind == MLA:
        attn_out, lc, rc = mla_mod.attend_absorbed(
            cfg, p["attn"], h, cache["latent"], cache["rope"], pos, positions)
        new_cache = {"latent": lc, "rope": rc}
    elif kind == RGLRU:
        attn_out, h_new, conv_buf = rglru_mod.apply_decode(
            cfg, p["rec"], h, cache["h"], cache["conv"])
        new_cache = {"h": h_new, "conv": conv_buf}
    elif kind == MLSTM:
        state = (cache["C"], cache["n"], cache["m"])
        attn_out, state, conv_buf = xlstm_mod.mlstm_apply_decode(
            cfg, p["rec"], h, state, cache["conv"])
        new_cache = {"C": state[0], "n": state[1], "m": state[2], "conv": conv_buf}
    elif kind == SLSTM:
        state = (cache["c"], cache["n"], cache["m"], cache["h"])
        attn_out, state, conv_buf = xlstm_mod.slstm_apply_decode(
            cfg, p["rec"], h, state, cache["conv"])
        new_cache = {"c": state[0], "n": state[1], "m": state[2],
                     "h": state[3], "conv": conv_buf}
    else:
        raise ValueError(kind)

    x = x + attn_out
    ff, _aux = _apply_ffn(cfg, p, layers.apply_norm(cfg, p["norm2"], x)
                          if "norm2" in p else x, run.is_moe)
    if ff is not None:
        x = x + ff
    return x, new_cache


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------
def _cache_leaf_shapes(cfg, run: Run, B: int, max_len: int) -> dict:
    kind = run.kind
    Hkv, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    f32 = jnp.float32
    K = cfg.conv1d_width
    if kind == ATTN:
        return {"k": ((B, max_len, Hkv, Dh), dt), "v": ((B, max_len, Hkv, Dh), dt)}
    if kind == LOCAL_ATTN:
        W = min(cfg.local_window, max_len)
        return {"k": ((B, W, Hkv, Dh), dt), "v": ((B, W, Hkv, Dh), dt)}
    if kind == MLA:
        m = cfg.mla
        return {"latent": ((B, max_len, m.kv_lora_rank), dt),
                "rope": ((B, max_len, m.qk_rope_head_dim), dt)}
    if kind == RGLRU:
        W = cfg.lru_width or cfg.d_model
        return {"h": ((B, W), f32), "conv": ((B, K - 1, W), dt)}
    if kind == MLSTM:
        f, qk, H, dk, dv = xlstm_mod.mlstm_dims(cfg)
        return {"C": ((B, H, dk, dv), f32), "n": ((B, H, dk), f32),
                "m": ((B, H), f32), "conv": ((B, K - 1, f), dt)}
    if kind == SLSTM:
        d = cfg.d_model
        return {"c": ((B, d), f32), "n": ((B, d), f32), "m": ((B, d), f32),
                "h": ((B, d), f32), "conv": ((B, K - 1, d), dt)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, B: int, max_len: int):
    """Zero cache (m-states get NEG fill)."""
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    for run in layer_runs(cfg):
        leaves = _cache_leaf_shapes(cfg, run, B, max_len)
        run_cache = {}
        for name, (shape, dt) in leaves.items():
            full = (run.count,) + shape if run.count > 1 else shape
            fill = xlstm_mod.NEG if name == "m" else 0
            run_cache[name] = jnp.full(full, fill, dt)
        cache[run.name] = run_cache
    return cache


def cache_logical_axes(cfg: ModelConfig, B: int, max_len: int):
    """Logical axes for the cache pytree (mirrors init_cache)."""
    ax: dict = {"pos": ()}
    for run in layer_runs(cfg):
        leaves = _cache_leaf_shapes(cfg, run, B, max_len)
        run_ax = {}
        for name, (shape, _dt) in leaves.items():
            if name in ("k", "v"):
                # sequence-sharded KV cache (distributed flash-decode);
                # local-attn ring buffers stay unsharded in seq (tiny)
                seq_ax = "kv_seq" if run.kind == ATTN else None
                a = ("batch", seq_ax, None, None)
            elif name in ("latent", "rope"):
                a = ("batch", "kv_seq", None)
            elif name == "C":
                a = ("batch", None, "act_heads", None)
            elif name == "conv":
                a = ("batch", None, "act_ffn")
            else:
                a = ("batch",) + (None,) * (len(shape) - 1)
            run_ax[name] = (("layer",) + a) if run.count > 1 else a
        ax[run.name] = run_ax
    return ax


# ---------------------------------------------------------------------------
# Embedding / head / frontends
# ---------------------------------------------------------------------------
def _embed_inputs(cfg, params, batch):
    """-> (x (B,S,d), loss_mask (B,S) or None)."""
    tokens = batch["tokens"]
    if cfg.frontend == "audio_stub":
        # tokens: (B, K, S) — sum the K codebook embeddings + sinusoidal pos
        emb = params["embed"]["embedding"]        # (K, V, d)
        x = jnp.zeros(tokens.shape[0:1] + tokens.shape[2:] + (cfg.d_model,),
                      emb.dtype)
        for kk in range(cfg.num_codebooks):
            x = x + jnp.take(emb[kk], tokens[:, kk], axis=0)
        S = x.shape[1]
        x = x + layers.sinusoidal_embed(jnp.arange(S), cfg.d_model)[None].astype(x.dtype)
        return x, None
    x = layers.embed(params["embed"], tokens, cfg.d_model)
    mask = None
    if cfg.frontend == "vision_stub":
        n = cfg.num_image_tokens
        pix = batch["pixel_embeds"].astype(x.dtype)   # (B, n, d) precomputed
        x = jnp.concatenate([pix, x[:, n:]], axis=1)
        mask = (jnp.arange(x.shape[1]) >= n)[None, :]
    return x, mask


def _head(cfg, params, x):
    if cfg.frontend == "audio_stub":
        B, S, _ = x.shape
        logits = (x @ params["head"]["w"]).astype(jnp.float32)
        return logits.reshape(B, S, cfg.num_codebooks, cfg.vocab_size)
    if cfg.tie_embeddings:
        return layers.unembed(params["embed"], x, cfg.logit_softcap)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, batch, *, remat: bool = False):
    """Full-sequence forward -> (logits, aux_loss)."""
    x, mask = _embed_inputs(cfg, params, batch)
    x = shard(x, ("batch", "seq", "embed"))
    aux = jnp.zeros((), jnp.float32)
    for run in layer_runs(cfg):
        p_run = params[run.name]

        def body(carry, p_slice, _run=run):
            xx, au = carry
            y, a, _ = block_apply_seq(cfg, _run, p_slice, xx, want_cache=False)
            return (y, au + a), None

        if remat:
            # full rematerialization: save only the per-layer block inputs
            # (the scan carry).  dots_*_saveable policies would pin every
            # projection output (~2GB/layer/chip at train_4k) — measured
            # 84GB/chip temps vs ~17GB with full remat (EXPERIMENTS §Dry-run).
            # MoE archs additionally save the dispatched capacity buffer
            # ('moe_dispatch', ~20MB/chip/layer) so the backward pass does
            # not repeat the expert all-to-all (§Perf iteration 4).
            if cfg.is_moe:
                body = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.save_only_these_names(
                        "moe_dispatch"))
            else:
                body = jax.checkpoint(body)
        if run.count > 1:
            (x, aux), _ = maybe_scan(body, (x, aux), p_run)
        else:
            (x, aux), _ = body((x, aux), p_run)
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = _head(cfg, params, x)
    return logits, aux, mask


def loss_fn(cfg: ModelConfig, params, batch, *, remat: bool = True):
    logits, aux, mask = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "audio_stub":
        # labels: (B, K, S) -> logits (B,S,K,V)
        lab = labels.transpose(0, 2, 1)
        loss = layers.cross_entropy(logits, lab)
    else:
        loss = layers.cross_entropy(logits, labels, mask=mask)
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux}


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    """-> (cache, last_token_logits)."""
    x, _mask = _embed_inputs(cfg, params, batch)
    x = shard(x, ("batch", "seq", "embed"))
    S = x.shape[1]
    cache: dict = {"pos": jnp.asarray(S, jnp.int32)}
    for run in layer_runs(cfg):
        p_run = params[run.name]

        def body(carry, p_slice, _run=run):
            xx = carry
            y, _a, c = block_apply_seq(cfg, _run, p_slice, xx,
                                       want_cache=True, max_len=max_len)
            return y, c

        if run.count > 1:
            x, run_cache = maybe_scan(body, x, p_run)
        else:
            x, run_cache = body(x, p_run)
        cache[run.name] = run_cache
    x = layers.apply_norm(cfg, params["final_norm"], x[:, -1:])
    return cache, _head(cfg, params, x)[:, 0]


def greedy_sample(cfg: ModelConfig, logits):
    """Greedy token selection designed to stay cheap under a vocab-sharded
    layout (§Perf iteration 7): argmax commutes with the vocab sharding, so
    the partitioner reduces (max, idx) pairs — O(B) on the wire — instead of
    gathering the (B, V) fp32 logits (131 MB/step for a 256k vocab)."""
    if cfg.frontend == "audio_stub":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, K)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (B,)


def serve_step_greedy(cfg: ModelConfig, params, cache, tokens_t):
    """decode_step + on-device greedy sampling: returns ((B,) int32, cache).
    The full-logits variant is decode_step (needed for temperature sampling
    off-device); this is the production greedy path."""
    logits, new_cache = decode_step(cfg, params, cache, tokens_t)
    return greedy_sample(cfg, logits), new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens_t):
    """One decode step.  tokens_t: (B,) int32 (or (B,K) audio).
    Returns (logits, new_cache)."""
    if cfg.frontend == "audio_stub":
        emb = params["embed"]["embedding"]
        x = jnp.zeros((tokens_t.shape[0], 1, cfg.d_model), emb.dtype)
        for kk in range(cfg.num_codebooks):
            x = x + jnp.take(emb[kk], tokens_t[:, kk: kk + 1], axis=0)
        x = x + layers.sinusoidal_embed(
            cache["pos"][None].astype(jnp.float32), cfg.d_model)[None].astype(x.dtype)
    else:
        x = layers.embed_onehot(params["embed"], tokens_t[:, None], cfg.d_model)
    x = shard(x, ("batch", None, "embed"))
    pos = cache["pos"]
    new_cache: dict = {"pos": pos + 1}
    for run in layer_runs(cfg):
        p_run = params[run.name]
        if run.count > 1:
            def body(carry, xs, _run=run):
                xx = carry
                p_slice, c_slice = xs
                y, c_new = block_apply_decode(cfg, _run, p_slice, xx, c_slice, pos)
                return y, c_new
            x, run_cache = maybe_scan(body, x, (p_run, cache[run.name]))
        else:
            x, run_cache = block_apply_decode(cfg, run, p_run, x,
                                              cache[run.name], pos)
        new_cache[run.name] = run_cache
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = _head(cfg, params, x)
    return logits[:, 0], new_cache
