"""Pure-jnp oracles for every kernel in repro/kernels (the ``ref.py`` of the
<name>.py + ops.py + ref.py convention).  Tests assert_allclose the Pallas
kernels (interpret mode) against these, sweeping shapes and dtypes.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# paper_suite
# ---------------------------------------------------------------------------
def maxpool(x):
    R, C = x.shape
    return x.reshape(R // 2, 2, C).max(axis=1)


def upsample(x):
    R, C = x.shape
    return jnp.broadcast_to(x[:, None, :], (R, 2, C)).reshape(2 * R, C)


def bnstats(x):
    xf = x.astype(jnp.float32)
    return jnp.stack([xf.sum(0), (xf * xf).sum(0)])


def im2col(x, K=4):
    outs = [jnp.concatenate([x[:, k:], x[:, :k]], axis=1) for k in range(K)]
    return jnp.concatenate(outs, axis=1)


def hist(x, bins=128):
    xf = x.astype(jnp.float32)
    b = jnp.clip((xf + 4.0) * (bins / 8.0), 0, bins - 1).astype(jnp.int32)
    return jnp.zeros((1, bins), jnp.float32).at[0, b.reshape(-1)].add(1.0)


def ethash_like(dag, x, w):
    bm = x.shape[0]
    R = dag.shape[0]
    out = jnp.zeros((bm, dag.shape[1]), jnp.float32)
    for s in range(R // bm):
        mix = (x + dag[s * bm:(s + 1) * bm]).astype(jnp.float32)
        out = out + jnp.tanh(mix @ w.astype(jnp.float32))
    return out


def hash_like(x, w, rounds=16):
    s = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    for _ in range(rounds):
        s = jnp.tanh(s @ wf)
    return s.astype(x.dtype)


# ---------------------------------------------------------------------------
# framework kernels
# ---------------------------------------------------------------------------
def matmul(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale)).astype(x.dtype)


def flash_attention(q, k, v, causal=True):
    """q,k,v: (B,S,H,D) — plain softmax attention oracle."""
    B, S, H, D = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def decode_attention(q, k, v, length):
    """q: (B,H,D); k,v: (B,S,Hkv,D); attend to first `length` positions."""
    B, H, D = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Hkv, rep, D)
    s = jnp.einsum("bhrd,bkhd->bhrk", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    valid = jnp.arange(k.shape[1])[None, None, None, :] < length
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrk,bkhd->bhrd", w.astype(v.dtype), v)
    return o.reshape(B, H, D)


def moe_gmm(xe, w_in, w_out, act="silu"):
    """xe: (E,C,d); w_in: (E,d,2f|f); w_out: (E,f,d)."""
    h = jnp.einsum("ecd,edf->ecf", xe, w_in)
    f = w_out.shape[1]
    if w_in.shape[-1] == 2 * f:
        g, u = jnp.split(h, 2, axis=-1)
        h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def adamw(p, g, m, v, *, lr, b1, b2, eps, wd, bc1, bc2):
    gf = g.astype(jnp.float32)
    m2 = b1 * m + (1 - b1) * gf
    v2 = b2 * v + (1 - b2) * gf * gf
    step = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps) + wd * p.astype(jnp.float32)
    return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2
