"""Beyond-paper table: horizontal fusion applied inside the framework
(the instances from DESIGN.md §4), with cost-model gains + numerics checks.

  dual_stream_decode — decode attention (memory) ⊕ FFN matmul (compute):
                       the paper's Ethash+Blake scenario inside a serving
                       step (two phase-shifted half-batches).
  adam_overlap       — optimizer update (memory) ⊕ dW matmul (compute):
                       backward/optimizer overlap.
  moe_gmm            — E independent expert FFNs as ONE kernel vs E
                       launches (the launch-amortization footnote at scale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core import autotuner, hfuse
from repro.core.cost_model import Schedule, hfused_cost, native_time
from repro.kernels import ref
from repro.kernels.adam import adamw_op
from repro.kernels.decode_attention import decode_attention_op
from repro.kernels.matmul import matmul_1d_op
from repro.kernels.moe_gmm import moe_gmm_op


def _verify_dual_stream():
    """Numerics: fused (decode_attn ⊕ matmul) == separate (reduced sizes)."""
    B, S, H, Hkv, D = 2, 512, 8, 2, 64
    att = decode_attention_op(B=B, S=S, H=H, Hkv=Hkv, D=D,
                              dtype=jnp.float32, ck=128)
    mm = matmul_1d_op(256, 128, 256, dtype=jnp.float32, bm=64)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    x = jax.random.normal(ks[3], (256, 128), jnp.float32)
    w = jax.random.normal(ks[4], (128, 256), jnp.float32) * 0.1
    res = autotuner.search((att, mm))
    fused = hfuse.generate(att, mm, res.best.sched, interpret=True)
    outs = fused(q, kc, vc, x, w)
    err = max(
        float(np.max(np.abs(np.asarray(outs[0])
                            - np.asarray(ref.decode_attention(q, kc, vc, S))))),
        float(np.max(np.abs(np.asarray(outs[3])
                            - np.asarray(ref.matmul(x, w))))))
    return res, err


def run():
    csv_row("instance", "memory_op", "compute_op", "sched",
            "native_us", "hfused_us", "speedup_pct", "max_err")

    # 1) chunked-prefill ⊕ decode overlap (the dual-stream serving mode):
    #    a decode wave's attention (memory-bound KV streaming, 128 seqs x
    #    32k cache per chip) fuses with a prefill chunk's FFN matmul
    #    (2048 tokens -> compute-bound).  NOTE the honest finding recorded
    #    in EXPERIMENTS §Paper-validation: decode FFN itself is memory-
    #    bound at serving batch sizes (weight streaming), so decode⊕decode
    #    fusion gains ~nothing on TPU — the profitable pairing is
    #    prefill-compute x decode-memory, the paper's scenario test applied
    #    through our planner.
    att = decode_attention_op(B=16, S=32768, H=8, Hkv=2, D=64,
                              dtype=jnp.bfloat16, ck=2048)  # 16 seqs/chip wave
    mm = matmul_1d_op(2048, 2048, 8192, dtype=jnp.bfloat16, bm=128)  # 2k-token prefill chunk
    res = autotuner.search((att, mm))
    _, err = _verify_dual_stream()
    csv_row("prefill_decode_overlap", att.name, mm.name,
            f"{res.best.sched.ra}:{res.best.sched.rb}",
            round((native_time(att) + native_time(mm)) * 1e6, 1),
            round(res.best.est.t_hfused * 1e6, 1),
            round(res.best.est.speedup_pct(), 1), f"{err:.1e}")

    # 2) optimizer/backward overlap: Adam update of a 128M-param slice
    #    (memory) ⊕ a dW matmul (compute)
    adam = adamw_op(R=1_048_576, dtype=jnp.bfloat16, bm=4096)  # 134M params
    dw = matmul_1d_op(4096, 4096, 4096, dtype=jnp.bfloat16, bm=512)
    res2 = autotuner.search((adam, dw))
    # numerics at reduced size
    adam_s = adamw_op(R=512, dtype=jnp.float32, bm=128)
    dw_s = matmul_1d_op(256, 128, 128, dtype=jnp.float32, bm=64)
    key = jax.random.PRNGKey(1)
    sc = jnp.zeros((1, 128), jnp.float32).at[0, 0].set(1e-3) \
        .at[0, 1].set(0.1).at[0, 2].set(0.05)
    p = jax.random.normal(key, (512, 128), jnp.float32)
    g = p * 0.01
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    x = jax.random.normal(key, (256, 128), jnp.float32)
    w = jax.random.normal(key, (128, 128), jnp.float32) * 0.1
    fused = hfuse.generate(adam_s, dw_s, res2.best.sched, interpret=True)
    outs = fused(sc, p, g, m, v, x, w)
    want_p, want_m, want_v = ref.adamw(p, g, m, v, lr=1e-3, b1=0.9, b2=0.95,
                                       eps=1e-8, wd=0.1, bc1=0.1, bc2=0.05)
    err2 = max(float(np.max(np.abs(np.asarray(outs[0]) - np.asarray(want_p)))),
               float(np.max(np.abs(np.asarray(outs[3])
                                   - np.asarray(ref.matmul(x, w))))))
    csv_row("adam_overlap", adam.name, dw.name,
            f"{res2.best.sched.ra}:{res2.best.sched.rb}",
            round((native_time(adam) + native_time(dw)) * 1e6, 1),
            round(res2.best.est.t_hfused * 1e6, 1),
            round(res2.best.est.speedup_pct(), 1), f"{err2:.1e}")

    # 3) grouped MoE at DECODE capacity (DeepSeek-V2 decode_32k: ~5 tokens
    #    per expert per chip): E tiny weight-streaming matmuls; separate
    #    launches pay E x (launch + ramp); the grouped kernel streams all
    #    expert weights in one pipeline.  (At train capacity the per-expert
    #    matmul is large and launch amortization vanishes -> ~0%: recorded.)
    from repro.core.cost_model import LAUNCH_S
    for C, tag in ((8, "decode"), (512, "train")):
        E, d, f = 160, 5120, 1536
        gmm = moe_gmm_op(E=E, C=C, d=d, f=f, bc=min(128, C))
        per_expert = moe_gmm_op(E=1, C=C, d=d, f=f, bc=min(128, C))
        t_sep = E * native_time(per_expert)
        t_grp = native_time(gmm)
        csv_row(f"moe_gmm_{tag}_C{C}", f"{E} expert FFNs",
                "one grouped kernel", "-",
                round(t_sep * 1e6, 1), round(t_grp * 1e6, 1),
                round(100 * (t_sep - t_grp) / t_sep, 1), "tested")


if __name__ == "__main__":
    run()
